"""BSP / MapReduce on stateless functions + storage shuffle (paper §3.3).

'More complex abstractions like dataflow or BSP can be implemented on top' —
this module is that layer: synchronized stages of stateless tasks with a
storage-backed shuffle between them.  No worker talks to another worker,
ever; the only channel is the store, exactly as in the paper.

Provides:
  * ``run_stage``   — one BSP superstep (map over items, barrier on results);
  * ``mapreduce``   — map → (hash shuffle) → reduce, used by word count;
  * ``terasort``    — sample → range-partition → merge, the Daytona-sort
                      two-stage algorithm of §3.3 with selectable
                      intermediate store (ObjectStore=S3 or KVStore=Redis);
  * phase accounting per task so benchmarks reproduce Fig 6's breakdown.

Lifecycle: each stage runs with ``gc=True`` (scheduler/result/input state is
freed at the stage barrier), and both ``mapreduce`` and ``terasort`` retire
their ``shuffle/{job}`` intermediates via ``shuffle.delete_intermediates``
once the consuming stage has merged — storage holds only live data between
stages, not the pipeline's history.
"""

from __future__ import annotations

import uuid
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.storage import KVStore, ObjectStore
from repro.storage import shuffle as shf

from .futures import get_all
from .wren import WrenExecutor


def run_stage(
    wex: WrenExecutor,
    fn: Callable[[Any], Any],
    items: Sequence[Any],
    *,
    timeout_s: float = 300.0,
    job_id: Optional[str] = None,
    gc: bool = False,
) -> List[Any]:
    """One BSP superstep: map + barrier.  The barrier's result fan-in rides
    ``get_all``'s single multi-get.  ``gc=True`` frees the superstep's
    scheduler/storage state once its results are in hand — multi-stage
    pipelines (mapreduce, terasort) use it so scheduler state stays bounded
    by the *current* stage, not the whole pipeline history."""
    job = job_id or f"stage-{uuid.uuid4().hex[:8]}"
    futures = wex.map(fn, items, job_id=job)
    out = get_all(futures, timeout_s=timeout_s)
    if gc:
        wex.finish_job(job)
    return out


# ---------------------------------------------------------------------------
# MapReduce (hash shuffle)
# ---------------------------------------------------------------------------

def mapreduce(
    wex: WrenExecutor,
    map_fn: Callable[[Any], List[Tuple[Any, Any]]],
    reduce_fn: Callable[[Any, List[Any]], Any],
    partitions: Sequence[Any],
    num_reducers: int,
    intermediate: Union[ObjectStore, KVStore, None] = None,
    *,
    timeout_s: float = 300.0,
) -> Dict[Any, Any]:
    """Classic MR: map_fn emits (k, v) pairs; reduce_fn folds values per key."""
    store = intermediate if intermediate is not None else wex.store
    job = f"mr-{uuid.uuid4().hex[:8]}"
    n_maps = len(partitions)

    def _map_task(arg: Tuple[int, Any]) -> Dict[str, float]:
        map_id, part = arg
        pairs = map_fn(part)
        buckets = shf.hash_partition(pairs, num_reducers)
        shf.write_partitions(store, job, map_id, buckets, worker=f"map{map_id}")
        return {"emitted": float(len(pairs))}

    def _reduce_task(part_id: int) -> Dict[Any, Any]:
        pairs = shf.read_partition_column(
            store, job, n_maps, part_id, worker=f"red{part_id}"
        )
        grouped: Dict[Any, List[Any]] = defaultdict(list)
        for k, v in pairs:
            grouped[k].append(v)
        return {k: reduce_fn(k, vs) for k, vs in grouped.items()}

    run_stage(wex, _map_task, list(enumerate(partitions)), timeout_s=timeout_s, gc=True)
    red_out = run_stage(
        wex, _reduce_task, list(range(num_reducers)), timeout_s=timeout_s, gc=True
    )
    # Shuffle-intermediate GC: the reduce barrier has consumed every
    # shuffle/{job} object, so retire the whole column space in one batched
    # delete — intermediates must not outlive the job (ROADMAP item).
    shf.delete_intermediates(store, job, n_maps, num_reducers, worker="driver")
    merged: Dict[Any, Any] = {}
    for d in red_out:
        merged.update(d)
    return merged


def word_count(
    wex: WrenExecutor,
    documents: Sequence[Sequence[str]],
    num_reducers: int,
    intermediate: Union[ObjectStore, KVStore, None] = None,
) -> Dict[str, int]:
    """The paper's word-count job (83.68M reviews / 333 partitions there)."""

    def map_fn(doc: Sequence[str]) -> List[Tuple[str, int]]:
        counts: Dict[str, int] = defaultdict(int)
        for line in doc:
            for w in line.split():
                counts[w] += 1
        return list(counts.items())

    def reduce_fn(_k: str, vs: List[int]) -> int:
        return int(sum(vs))

    return mapreduce(wex, map_fn, reduce_fn, documents, num_reducers, intermediate)


# ---------------------------------------------------------------------------
# Terasort (range shuffle) — paper §3.3 Daytona sort
# ---------------------------------------------------------------------------

@dataclass
class SortReport:
    n_records: int = 0
    n_intermediate_objects: int = 0
    splitters: int = 0
    phase_vtime: Dict[str, float] = field(default_factory=dict)
    hottest_shard_vtime: float = 0.0


def terasort(
    wex: WrenExecutor,
    input_keys: List[str],
    output_prefix: str,
    num_partitions: int,
    intermediate: Union[ObjectStore, KVStore],
    *,
    sample_per_task: int = 64,
    timeout_s: float = 600.0,
) -> SortReport:
    """Two-stage sort: partition (range-partition + write intermediates) then
    merge (read column, merge-sort, write output).  Input/output live in the
    main object store (S3); intermediates in ``intermediate`` — the paper
    moved these to Redis because S3's request throughput collapsed under
    n_tasks² objects."""
    store = wex.store
    job = f"sort-{uuid.uuid4().hex[:8]}"
    n_maps = len(input_keys)
    report = SortReport()

    # --- stage 0: sample for splitters (TeraSort sampler) -----------------
    def _sample_task(key: str) -> List[bytes]:
        recs: np.ndarray = store.get(key, worker="sampler")
        idx = np.linspace(0, len(recs) - 1, min(sample_per_task, len(recs))).astype(int)
        return [shf.record_sort_key(recs[i]) for i in idx]

    samples = run_stage(wex, _sample_task, input_keys, timeout_s=timeout_s, gc=True)
    flat = [s for chunk in samples for s in chunk]
    splitters = shf.sample_splitters(flat, num_partitions)
    report.splitters = len(splitters)

    # --- stage 1: partition -------------------------------------------------
    def _partition_task(arg: Tuple[int, str]) -> Dict[str, Any]:
        map_id, key = arg
        recs: np.ndarray = store.get(key, worker=f"part{map_id}")
        parts = shf.range_partition(list(recs), splitters, key=shf.record_sort_key)
        n_objs = shf.write_partitions(
            intermediate, job, map_id, parts, worker=f"part{map_id}"
        )
        return {"records": len(recs), "objects": n_objs}

    part_out = run_stage(
        wex, _partition_task, list(enumerate(input_keys)), timeout_s=timeout_s, gc=True
    )
    report.n_records = int(sum(o["records"] for o in part_out))
    report.n_intermediate_objects = int(sum(o["objects"] for o in part_out))

    # --- stage 2: merge ------------------------------------------------------
    def _merge_task(part_id: int) -> int:
        chunk = shf.read_partition_column(
            intermediate, job, n_maps, part_id, worker=f"merge{part_id}"
        )
        chunk.sort(key=shf.record_sort_key)
        out = np.stack(chunk) if chunk else np.zeros((0, 100), np.uint8)
        store.put(f"{output_prefix}/part{part_id:06d}", out, worker=f"merge{part_id}")
        return len(chunk)

    merged_counts = run_stage(
        wex, _merge_task, list(range(num_partitions)), timeout_s=timeout_s, gc=True
    )
    assert sum(merged_counts) == report.n_records, "sort lost records"
    # Shuffle-intermediate GC: merge consumed every intermediate column;
    # drop shuffle/{job} in one batched delete before reporting.
    shf.delete_intermediates(
        intermediate, job, n_maps, num_partitions, worker="driver"
    )

    # --- phase accounting (Fig 6) -------------------------------------------
    per_worker = store.ledger.per_worker()
    phases: Dict[str, float] = defaultdict(float)
    for w, ops in per_worker.items():
        for op, (nbytes, vt) in ops.items():
            if w.startswith("part"):
                phases[f"partition_{op}"] += vt
            elif w.startswith("merge"):
                phases[f"merge_{op}"] += vt
    if isinstance(intermediate, KVStore):
        report.hottest_shard_vtime = intermediate.hottest_shard_vtime()
        for i, st in enumerate(intermediate.shard_stats()):
            phases[f"kv_shard{i}"] += st.vtime_s
    report.phase_vtime = dict(phases)
    return report


def verify_sorted(store: ObjectStore, output_prefix: str) -> bool:
    """Global order check across output partitions."""
    prev_last: Optional[bytes] = None
    keys = store.list(output_prefix)
    parts = store.get_many(keys, missing="error")
    for key in keys:
        recs: np.ndarray = parts[key]
        if len(recs) == 0:
            continue
        keys = [shf.record_sort_key(r) for r in recs]
        if keys != sorted(keys):
            return False
        if prev_last is not None and keys[0] < prev_last:
            return False
        prev_last = keys[-1]
    return True
