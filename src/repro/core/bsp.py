"""BSP / MapReduce on stateless functions + storage shuffle (paper §3.3),
now driver-crash-tolerant: every job is a *re-entrant replay* of a
KV-resident manifest (``core/jobs.py``).

'More complex abstractions like dataflow or BSP can be implemented on top' —
this module is that layer: synchronized stages of stateless tasks with a
storage-backed shuffle between them.  No worker talks to another worker,
ever; the only channel is the store, exactly as in the paper.

Provides:
  * ``run_stage``   — one BSP superstep (map over items, barrier on results);
  * ``mapreduce``   — map → (hash shuffle) → reduce, used by word count;
  * ``terasort``    — sample → range-partition → merge, the Daytona-sort
                      two-stage algorithm of §3.3 with selectable
                      intermediate store (ObjectStore=S3 or KVStore=Redis);
  * ``adopt_job``   — the failover entry point: wait for a job's driver
                      lease to lapse, fence it at ``term + 1``, and replay
                      the manifest to completion from the last barrier;
  * phase accounting per task so benchmarks reproduce Fig 6's breakdown.

Re-entrancy contract (the PR-7 tentpole): before a job runs anything, its
manifest and stage plans land in the KV under ``sched/job/{job}/`` via
:func:`jobs.commit_records` — one first-writer-wins ``eval_many``, so two
drivers planning the same stage converge on one plan.  Each completed stage
writes its barrier record (the outputs, in task order) *before* its
scheduler state is GC'd, so a driver killed at any instant leaves a
resumable prefix: the replay skips recorded barriers, rebuilds the exact
``TaskSpec`` set from a stored plan (task ids are deterministic hashes of
job/function/input), resubmits only tasks whose result keys don't exist,
and lets the task plane's epoch fencing converge any duplicates the dead
driver left queued or leased.

Lifecycle: each stage's scheduler state is freed at its barrier, both
``mapreduce`` and ``terasort`` retire their ``shuffle/{job}`` intermediates
via ``shuffle.delete_intermediates`` once the consuming stage has merged
(the manifest's GC plan — re-derived from ``meta`` on replay), and the
final ``finish_job`` drops the manifest keyspace itself behind the job's
tombstone — storage holds only live data between stages, not the
pipeline's history.
"""

from __future__ import annotations

import uuid
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.storage import KVStore, ObjectStore
from repro.storage import shuffle as shf

from . import jobs
from .functions import FunctionSpec, TaskSpec, stage_inputs
from .futures import ResultFuture, get_all
from .wren import WrenExecutor


# ---------------------------------------------------------------------------
# the replay framework: plan → run → barrier, all records KV-resident
# ---------------------------------------------------------------------------

def _register(wex: WrenExecutor, job: str) -> int:
    term = wex.register_driver(job)
    if term is None:
        raise RuntimeError(
            f"job {job!r} already has a live driver — a second submitter "
            "must wait for its lease to lapse (bsp.adopt_job) instead of "
            "racing it"
        )
    return term


def _build_plan(
    wex: WrenExecutor,
    job: str,
    idx: int,
    fn: Callable[[Any], Any],
    items: Sequence[Any],
    *,
    term: int,
    stage_job: Optional[str] = None,
) -> dict:
    """Materialize a stage plan: register the stage function (content-
    addressed) and stage all inputs (one batched put), then return the
    record that makes the stage rebuildable by any driver — function key,
    input keys in task-index order, and the stage's scheduler job id.
    ``TaskSpec.make`` is a deterministic hash of exactly these, so every
    driver holding this record derives the identical task set."""
    func = FunctionSpec.register(wex.store, fn, worker="driver")
    sj = stage_job if stage_job is not None else f"{job}/s{idx}"
    input_keys = stage_inputs(wex.store, sj, list(items), worker="driver")
    return {
        "func_key": func.key,
        "func_name": func.name,
        "input_keys": input_keys,
        "stage_job": sj,
        "term": term,
    }


def _run_planned(wex: WrenExecutor, plan: dict, *, timeout_s: float) -> List[Any]:
    """Run (or resume) a planned stage: rebuild the deterministic task set,
    probe which results already exist (one batched existence check), submit
    only the missing tasks, and barrier on all of them.  A task the dead
    driver left queued or leased may briefly run twice — the task plane's
    epoch fencing and first-writer-wins result publish make the duplicate
    converge, exactly as a speculative copy does."""
    func = FunctionSpec(key=plan["func_key"], name=plan["func_name"])
    tasks = [
        TaskSpec.make(plan["stage_job"], func, key, i)
        for i, key in enumerate(plan["input_keys"])
    ]
    present = wex.store.exists_many([t.result_key for t in tasks], worker="driver")
    missing = [t for t in tasks if t.result_key not in present]
    if missing:
        wex.scheduler.submit_many(missing)
    return get_all([ResultFuture(wex.store, t) for t in tasks], timeout_s=timeout_s)


def _stage_barrier(
    wex: WrenExecutor,
    job: str,
    idx: int,
    plan: dict,
    outputs: List[Any],
    *,
    term: int,
    gc_stage: bool = True,
) -> List[Any]:
    """Commit the barrier record, THEN free the stage's scheduler state.
    The order is the crash-safety invariant: a driver dying between the two
    leaves the barrier durable (the adopter skips the stage), and dying
    before the commit leaves the results in the store for the adopter's
    resubmission probe.  First-writer-wins: a zombie and its adopter both
    proceed with the stored outputs."""
    key = jobs.barrier_key(job, idx)
    stored = jobs.commit_records(
        wex.kv, {key: {"outputs": outputs, "term": term}}
    )
    if gc_stage:
        wex.finish_job(plan["stage_job"])
    return stored[key]["outputs"]


def _replay_stage(
    wex: WrenExecutor,
    job: str,
    idx: int,
    planner: Callable[[], Tuple[Callable[[Any], Any], Sequence[Any]]],
    *,
    term: int,
    timeout_s: float,
) -> List[Any]:
    """One stage of a manifest replay: recorded barrier → return instantly;
    recorded plan → resume it; neither → plan it now (``planner`` re-derives
    the stage function and items from earlier barriers / manifest meta) and
    commit first-writer-wins before running."""
    done = jobs.read_barrier(wex.kv, job, idx, worker="driver")
    if done is not None:
        return done["outputs"]
    plan = jobs.read_stage(wex.kv, job, idx, worker="driver")
    if plan is None:
        fn, items = planner()
        built = _build_plan(wex, job, idx, fn, items, term=term)
        plan = jobs.commit_records(wex.kv, {jobs.stage_key(job, idx): built})[
            jobs.stage_key(job, idx)
        ]
    outputs = _run_planned(wex, plan, timeout_s=timeout_s)
    return _stage_barrier(wex, job, idx, plan, outputs, term=term)


def _intermediate_meta(wex: WrenExecutor, store: Union[ObjectStore, KVStore]) -> Any:
    """How the manifest records which store holds the shuffle intermediates:
    the driver's own store (portable by construction), a file-backed
    handle's reconnect spec (its directory root is the endpoint), or None
    for an opaque in-memory handle — adoptable only with an explicit
    ``intermediate=`` from the adopter."""
    if store is wex.store:
        return "driver-store"
    return store._endpoint_spec()


def _resolve_intermediate(
    wex: WrenExecutor, spec: Any
) -> Union[ObjectStore, KVStore]:
    if spec == "driver-store":
        return wex.store
    if spec is None:
        raise RuntimeError(
            "this job's shuffle intermediate store is in-memory (the "
            "manifest carries no reconnect spec); pass intermediate= to "
            "adopt_job, or use a FileBackend/FileKVStore-backed handle"
        )
    from repro.storage.object_store import _reconnect

    return _reconnect(spec)


# ---------------------------------------------------------------------------
# run_stage: one superstep, manifest-backed
# ---------------------------------------------------------------------------

def run_stage(
    wex: WrenExecutor,
    fn: Callable[[Any], Any],
    items: Sequence[Any],
    *,
    timeout_s: float = 300.0,
    job_id: Optional[str] = None,
    gc: bool = False,
) -> List[Any]:
    """One BSP superstep: map + barrier, as a single-stage manifest job.
    The manifest and the stage plan land in ONE first-writer-wins commit,
    so an adopter never observes a manifest whose stage it cannot rebuild.
    Re-entrant: calling again with the same ``job_id`` (same process or
    not) resumes rather than resubmits — a recorded barrier returns the
    stored outputs with no task traffic at all.  ``gc=True`` frees the
    superstep's scheduler/storage state (manifest included) once its
    results are in hand."""
    job = job_id or f"stage-{uuid.uuid4().hex[:8]}"
    term = _register(wex, job)
    try:
        manifest = jobs.read_manifest(wex.kv, job, worker="driver")
        if manifest is None:
            plan = _build_plan(wex, job, 0, fn, items, term=term, stage_job=job)
            stored = jobs.commit_records(
                wex.kv,
                {
                    jobs.manifest_key(job): {
                        "job": job,
                        "kind": "stage",
                        "meta": {"n_items": len(plan["input_keys"]), "gc": bool(gc)},
                        "term": term,
                    },
                    jobs.stage_key(job, 0): plan,
                },
            )
            manifest = stored[jobs.manifest_key(job)]
        # The caller's gc flag governs THIS call (a re-entrant caller may
        # keep the job around on one call and retire it on the next); the
        # manifest's recorded flag is the adopter's default.
        return _replay_stage_job(
            wex, job, manifest["meta"], term, timeout_s=timeout_s, gc=bool(gc)
        )
    except BaseException:
        wex.release_driver(job)  # errored out: let an adopter take over now
        raise


def _replay_stage_job(
    wex: WrenExecutor,
    job: str,
    meta: dict,
    term: int,
    *,
    timeout_s: float,
    gc: Optional[bool] = None,
) -> List[Any]:
    done = jobs.read_barrier(wex.kv, job, 0, worker="driver")
    if done is not None:
        out = done["outputs"]
    else:
        plan = jobs.read_stage(wex.kv, job, 0, worker="driver")
        if plan is None:
            raise RuntimeError(
                f"job {job!r}: manifest present but stage 0 unplanned — "
                "run_stage commits both atomically, so this manifest is "
                "corrupt"
            )
        out = _run_planned(wex, plan, timeout_s=timeout_s)
        out = _stage_barrier(wex, job, 0, plan, out, term=term, gc_stage=False)
    if meta.get("gc") if gc is None else gc:
        wex.finish_job(job)  # stage job == job: one GC drops manifest + state
    else:
        wex.release_driver(job)
    return out


# ---------------------------------------------------------------------------
# MapReduce (hash shuffle)
# ---------------------------------------------------------------------------

def _mr_map_task(
    map_fn: Callable[[Any], List[Tuple[Any, Any]]],
    store: Union[ObjectStore, KVStore],
    job: str,
    num_reducers: int,
) -> Callable[[Tuple[int, Any]], Dict[str, float]]:
    def _map_task(arg: Tuple[int, Any]) -> Dict[str, float]:
        map_id, part = arg
        pairs = map_fn(part)
        buckets = shf.hash_partition(pairs, num_reducers)
        shf.write_partitions(store, job, map_id, buckets, worker=f"map{map_id}")
        return {"emitted": float(len(pairs))}

    return _map_task


def _mr_reduce_task(
    reduce_fn: Callable[[Any, List[Any]], Any],
    store: Union[ObjectStore, KVStore],
    job: str,
    n_maps: int,
) -> Callable[[int], Dict[Any, Any]]:
    def _reduce_task(part_id: int) -> Dict[Any, Any]:
        pairs = shf.read_partition_column(
            store, job, n_maps, part_id, worker=f"red{part_id}"
        )
        grouped: Dict[Any, List[Any]] = defaultdict(list)
        for k, v in pairs:
            grouped[k].append(v)
        return {k: reduce_fn(k, vs) for k, vs in grouped.items()}

    return _reduce_task


def mapreduce(
    wex: WrenExecutor,
    map_fn: Callable[[Any], List[Tuple[Any, Any]]],
    reduce_fn: Callable[[Any, List[Any]], Any],
    partitions: Sequence[Any],
    num_reducers: int,
    intermediate: Union[ObjectStore, KVStore, None] = None,
    *,
    timeout_s: float = 300.0,
    job_id: Optional[str] = None,
) -> Dict[Any, Any]:
    """Classic MR: map_fn emits (k, v) pairs; reduce_fn folds values per key.

    Manifest-backed and re-entrant: the manifest (with the reduce function
    registered content-addressed and the shuffle/GC plan in ``meta``) and
    the map-stage plan commit in one first-writer-wins batch before any
    task is submitted.  A driver killed mid-shuffle is resumed by
    ``adopt_job`` from the last recorded barrier; the submitting process
    itself can also re-call with the same ``job_id`` to resume."""
    store = intermediate if intermediate is not None else wex.store
    job = job_id or f"mr-{uuid.uuid4().hex[:8]}"
    term = _register(wex, job)
    try:
        manifest = jobs.read_manifest(wex.kv, job, worker="driver")
        if manifest is None:
            reduce_func = FunctionSpec.register(wex.store, reduce_fn, worker="driver")
            plan0 = _build_plan(
                wex,
                job,
                0,
                _mr_map_task(map_fn, store, job, num_reducers),
                list(enumerate(partitions)),
                term=term,
            )
            meta = {
                "n_maps": len(partitions),
                "num_reducers": int(num_reducers),
                "reduce_fn_key": reduce_func.key,
                "reduce_fn_name": reduce_func.name,
                "intermediate": _intermediate_meta(wex, store),
            }
            stored = jobs.commit_records(
                wex.kv,
                {
                    jobs.manifest_key(job): {
                        "job": job,
                        "kind": "mapreduce",
                        "meta": meta,
                        "term": term,
                    },
                    jobs.stage_key(job, 0): plan0,
                },
            )
            manifest = stored[jobs.manifest_key(job)]
        return _replay_mapreduce(
            wex,
            job,
            manifest["meta"],
            term,
            store=store,
            reduce_fn=reduce_fn,
            timeout_s=timeout_s,
        )
    except BaseException:
        wex.release_driver(job)
        raise


def _replay_mapreduce(
    wex: WrenExecutor,
    job: str,
    meta: dict,
    term: int,
    *,
    store: Union[ObjectStore, KVStore, None] = None,
    reduce_fn: Optional[Callable[[Any, List[Any]], Any]] = None,
    timeout_s: float = 300.0,
) -> Dict[Any, Any]:
    """Replay a mapreduce manifest to completion (detect/fence already done
    by the caller).  An adopter reconstructs the reduce closure from the
    manifest's registered function key; the submitting driver passes its
    live ``reduce_fn`` and skips the load.  Either way the committed stage
    plan — not the locally built closure — is what names the tasks, so
    racing drivers converge on one task set."""
    if store is None:
        store = _resolve_intermediate(wex, meta.get("intermediate"))
    n_maps = int(meta["n_maps"])
    num_reducers = int(meta["num_reducers"])

    def _plan_map() -> Tuple[Callable[[Any], Any], Sequence[Any]]:
        raise RuntimeError(
            f"job {job!r}: map stage unplanned — mapreduce commits the map "
            "plan with the manifest, so this manifest is corrupt"
        )

    def _plan_reduce() -> Tuple[Callable[[Any], Any], Sequence[Any]]:
        rf = reduce_fn
        if rf is None:
            rf = FunctionSpec(
                key=meta["reduce_fn_key"], name=meta["reduce_fn_name"]
            ).load(wex.store, worker="driver")
        return _mr_reduce_task(rf, store, job, n_maps), list(range(num_reducers))

    _replay_stage(wex, job, 0, _plan_map, term=term, timeout_s=timeout_s)
    red_out = _replay_stage(wex, job, 1, _plan_reduce, term=term, timeout_s=timeout_s)
    # Shuffle-intermediate GC (the manifest's GC plan, re-derived from
    # meta): the reduce barrier has consumed every shuffle/{job} object, so
    # retire the whole column space in one batched delete — intermediates
    # must not outlive the job.
    shf.delete_intermediates(store, job, n_maps, num_reducers, worker="driver")
    merged: Dict[Any, Any] = {}
    for d in red_out:
        merged.update(d)
    # Terminal GC: tombstone the job and drop its manifest keyspace (the
    # per-stage scheduler state went at each barrier; finish_job on the
    # stage jobs is idempotent and covers a crash between barrier and GC).
    wex.finish_job(f"{job}/s0")
    wex.finish_job(f"{job}/s1")
    wex.finish_job(job)
    return merged


def word_count(
    wex: WrenExecutor,
    documents: Sequence[Sequence[str]],
    num_reducers: int,
    intermediate: Union[ObjectStore, KVStore, None] = None,
) -> Dict[str, int]:
    """The paper's word-count job (83.68M reviews / 333 partitions there)."""

    def map_fn(doc: Sequence[str]) -> List[Tuple[str, int]]:
        counts: Dict[str, int] = defaultdict(int)
        for line in doc:
            for w in line.split():
                counts[w] += 1
        return list(counts.items())

    def reduce_fn(_k: str, vs: List[int]) -> int:
        return int(sum(vs))

    return mapreduce(wex, map_fn, reduce_fn, documents, num_reducers, intermediate)


# ---------------------------------------------------------------------------
# Terasort (range shuffle) — paper §3.3 Daytona sort
# ---------------------------------------------------------------------------

@dataclass
class SortReport:
    n_records: int = 0
    n_intermediate_objects: int = 0
    splitters: int = 0
    phase_vtime: Dict[str, float] = field(default_factory=dict)
    hottest_shard_vtime: float = 0.0


def _sort_sample_task(
    store: ObjectStore, sample_per_task: int
) -> Callable[[str], List[bytes]]:
    def _sample_task(key: str) -> List[bytes]:
        recs: np.ndarray = store.get(key, worker="sampler")
        idx = np.linspace(0, len(recs) - 1, min(sample_per_task, len(recs))).astype(int)
        return [shf.record_sort_key(recs[i]) for i in idx]

    return _sample_task


def _sort_partition_task(
    store: ObjectStore,
    intermediate: Union[ObjectStore, KVStore],
    job: str,
    splitters: List[bytes],
) -> Callable[[Tuple[int, str]], Dict[str, Any]]:
    def _partition_task(arg: Tuple[int, str]) -> Dict[str, Any]:
        map_id, key = arg
        recs: np.ndarray = store.get(key, worker=f"part{map_id}")
        parts = shf.range_partition(list(recs), splitters, key=shf.record_sort_key)
        n_objs = shf.write_partitions(
            intermediate, job, map_id, parts, worker=f"part{map_id}"
        )
        return {"records": len(recs), "objects": n_objs}

    return _partition_task


def _sort_merge_task(
    store: ObjectStore,
    intermediate: Union[ObjectStore, KVStore],
    job: str,
    n_maps: int,
    output_prefix: str,
) -> Callable[[int], int]:
    def _merge_task(part_id: int) -> int:
        chunk = shf.read_partition_column(
            intermediate, job, n_maps, part_id, worker=f"merge{part_id}"
        )
        chunk.sort(key=shf.record_sort_key)
        out = np.stack(chunk) if chunk else np.zeros((0, 100), np.uint8)
        store.put(f"{output_prefix}/part{part_id:06d}", out, worker=f"merge{part_id}")
        return len(chunk)

    return _merge_task


def terasort(
    wex: WrenExecutor,
    input_keys: List[str],
    output_prefix: str,
    num_partitions: int,
    intermediate: Union[ObjectStore, KVStore],
    *,
    sample_per_task: int = 64,
    timeout_s: float = 600.0,
    job_id: Optional[str] = None,
) -> SortReport:
    """Two-stage sort: partition (range-partition + write intermediates) then
    merge (read column, merge-sort, write output).  Input/output live in the
    main object store (S3); intermediates in ``intermediate`` — the paper
    moved these to Redis because S3's request throughput collapsed under
    n_tasks² objects.

    Manifest-backed: every stage is re-derivable from ``meta`` alone (the
    splitters come out of the recorded sample barrier), so an adopter needs
    no state from the dead driver — not even a registered user function."""
    job = job_id or f"sort-{uuid.uuid4().hex[:8]}"
    term = _register(wex, job)
    try:
        manifest = jobs.read_manifest(wex.kv, job, worker="driver")
        if manifest is None:
            meta = {
                "input_keys": list(input_keys),
                "output_prefix": output_prefix,
                "num_partitions": int(num_partitions),
                "sample_per_task": int(sample_per_task),
                "intermediate": _intermediate_meta(wex, intermediate),
            }
            stored = jobs.commit_records(
                wex.kv,
                {
                    jobs.manifest_key(job): {
                        "job": job,
                        "kind": "terasort",
                        "meta": meta,
                        "term": term,
                    }
                },
            )
            manifest = stored[jobs.manifest_key(job)]
        return _replay_terasort(
            wex,
            job,
            manifest["meta"],
            term,
            intermediate=intermediate,
            timeout_s=timeout_s,
        )
    except BaseException:
        wex.release_driver(job)
        raise


def _replay_terasort(
    wex: WrenExecutor,
    job: str,
    meta: dict,
    term: int,
    *,
    intermediate: Union[ObjectStore, KVStore, None] = None,
    timeout_s: float = 600.0,
) -> SortReport:
    store = wex.store
    if intermediate is None:
        intermediate = _resolve_intermediate(wex, meta.get("intermediate"))
    input_keys = list(meta["input_keys"])
    output_prefix = meta["output_prefix"]
    num_partitions = int(meta["num_partitions"])
    sample_per_task = int(meta["sample_per_task"])
    n_maps = len(input_keys)
    report = SortReport()

    # --- stage 0: sample for splitters (TeraSort sampler) -----------------
    def _plan_sample() -> Tuple[Callable[[Any], Any], Sequence[Any]]:
        return _sort_sample_task(store, sample_per_task), list(input_keys)

    samples = _replay_stage(wex, job, 0, _plan_sample, term=term, timeout_s=timeout_s)
    flat = [s for chunk in samples for s in chunk]
    # Deterministic given the recorded sample barrier: every driver derives
    # the same splitters, hence the same partition-stage plan.
    splitters = shf.sample_splitters(flat, num_partitions)
    report.splitters = len(splitters)

    # --- stage 1: partition -------------------------------------------------
    def _plan_partition() -> Tuple[Callable[[Any], Any], Sequence[Any]]:
        return (
            _sort_partition_task(store, intermediate, job, splitters),
            list(enumerate(input_keys)),
        )

    part_out = _replay_stage(wex, job, 1, _plan_partition, term=term, timeout_s=timeout_s)
    report.n_records = int(sum(o["records"] for o in part_out))
    report.n_intermediate_objects = int(sum(o["objects"] for o in part_out))

    # --- stage 2: merge ------------------------------------------------------
    def _plan_merge() -> Tuple[Callable[[Any], Any], Sequence[Any]]:
        return (
            _sort_merge_task(store, intermediate, job, n_maps, output_prefix),
            list(range(num_partitions)),
        )

    merged_counts = _replay_stage(wex, job, 2, _plan_merge, term=term, timeout_s=timeout_s)
    assert sum(merged_counts) == report.n_records, "sort lost records"
    # Shuffle-intermediate GC (the manifest's GC plan): merge consumed every
    # intermediate column; drop shuffle/{job} in one batched delete.
    shf.delete_intermediates(
        intermediate, job, n_maps, num_partitions, worker="driver"
    )

    # --- phase accounting (Fig 6) -------------------------------------------
    per_worker = store.ledger.per_worker()
    phases: Dict[str, float] = defaultdict(float)
    for w, ops in per_worker.items():
        for op, (nbytes, vt) in ops.items():
            if w.startswith("part"):
                phases[f"partition_{op}"] += vt
            elif w.startswith("merge"):
                phases[f"merge_{op}"] += vt
    if isinstance(intermediate, KVStore):
        report.hottest_shard_vtime = intermediate.hottest_shard_vtime()
        for i, st in enumerate(intermediate.shard_stats()):
            phases[f"kv_shard{i}"] += st.vtime_s
    report.phase_vtime = dict(phases)
    for idx in range(3):
        wex.finish_job(f"{job}/s{idx}")
    wex.finish_job(job)
    return report


# ---------------------------------------------------------------------------
# adoption: the driver-failover entry point
# ---------------------------------------------------------------------------

def adopt_job(
    wex: WrenExecutor,
    job_id: str,
    *,
    wait_timeout_s: Optional[float] = None,
    timeout_s: float = 600.0,
    intermediate: Union[ObjectStore, KVStore, None] = None,
) -> Any:
    """Adopt and finish another driver's job (the protocol of
    ``core/jobs.py``): **detect** — block on the driver lease's shard watch
    until it is absent, released, or past its expiry; **fence** — take the
    lease at ``term + 1``, so the dead driver's in-flight heartbeats fail;
    **replay** — re-run the manifest, skipping recorded barriers and
    resubmitting only tasks without results; **barrier** — each finished
    stage commits its record before its state is GC'd.

    Returns exactly what the original submitting call would have returned
    (``mapreduce``'s merged dict, ``terasort``'s ``SortReport``,
    ``run_stage``'s output list), or ``None`` if the job already finished
    and was GC'd.  Raises ``TimeoutError`` if ``wait_timeout_s`` elapses
    with the original driver still heartbeating.  ``intermediate`` is only
    needed when the job's shuffle store was an in-memory handle the
    manifest cannot describe."""
    if not jobs.wait_for_driver_expiry(wex.kv, job_id, wait_timeout_s, worker="driver"):
        raise TimeoutError(
            f"driver of job {job_id!r} still heartbeating after {wait_timeout_s}s"
        )
    if jobs.job_finished(wex.kv, job_id, worker="driver"):
        return None  # finished and GC'd: nothing left to adopt
    term = _register(wex, job_id)
    try:
        manifest = jobs.read_manifest(wex.kv, job_id, worker="driver")
        if manifest is None:
            # finish_job raced us between the tombstone probe and the
            # takeover; re-finish to scrub the driver record the takeover
            # re-created (idempotent behind the existing tombstone).
            wex.finish_job(job_id)
            return None
        kind, meta = manifest["kind"], manifest["meta"]
        if kind == "mapreduce":
            return _replay_mapreduce(
                wex, job_id, meta, term, store=intermediate, timeout_s=timeout_s
            )
        if kind == "terasort":
            return _replay_terasort(
                wex, job_id, meta, term, intermediate=intermediate, timeout_s=timeout_s
            )
        if kind == "stage":
            return _replay_stage_job(wex, job_id, meta, term, timeout_s=timeout_s)
        raise ValueError(f"unknown manifest kind {kind!r} for job {job_id!r}")
    except BaseException:
        wex.release_driver(job_id)
        raise


def verify_sorted(store: ObjectStore, output_prefix: str) -> bool:
    """Global order check across output partitions."""
    prev_last: Optional[bytes] = None
    part_keys = store.list(output_prefix)
    parts = store.get_many(part_keys, missing="error")
    for key in part_keys:
        recs: np.ndarray = parts[key]
        if len(recs) == 0:
            continue
        sort_keys = [shf.record_sort_key(r) for r in recs]
        if sort_keys != sorted(sort_keys):
            return False
        if prev_last is not None and sort_keys[0] < prev_last:
            return False
        prev_last = sort_keys[-1]
    return True
