"""Futures over storage keys.

A PyWren future is just 'does the result key exist yet?'.  The future does
not talk to workers or the scheduler — completion is signalled purely by the
atomic existence of the result object, so futures survive scheduler restarts
and work across processes (anyone with the store handle can wait).

Event-driven waiting: ``result()``/``wait()`` block on the store's key-watch
condition (see ``ObjectStore.notify_put``) instead of sleep-polling.  A
publish through the same store handle wakes waiters immediately, and a
publish from *another process* over a shared ``FileBackend`` is relayed by
the backend's watch thread — no built-in backend needs a fallback tick
anymore.  The ``poll_s`` parameters are retained for backward compatibility
and force one (counted in ``ObjectStore.fallback_tick_waits``); waiting
over *multiple distinct backends* in one ``wait`` call is the only other
tick user left.

Batched resolution: ``get_all`` waits for every result key, then fetches
all uncached results in a *single* ``ObjectStore.get_many`` — one amortized
round-trip for the whole fan-in instead of one modeled request per future.
"""

from __future__ import annotations

import time
from typing import Any, List, Optional, Sequence, Tuple

from repro.storage import ObjectStore
from repro.storage.object_store import WATCH_FALLBACK_TICK_S

from .functions import TaskResult, TaskSpec

ALL_COMPLETED = "ALL_COMPLETED"
ANY_COMPLETED = "ANY_COMPLETED"
ALWAYS = "ALWAYS"


class ResultFuture:
    def __init__(self, store: ObjectStore, task: TaskSpec) -> None:
        self.store = store
        self.task = task
        self._cached: Optional[TaskResult] = None
        self._seen_done = False  # result key observed present (sticky:
        # publishes are if_absent, so a done future can never un-done)

    @property
    def result_key(self) -> str:
        return self.task.result_key

    def done(self) -> bool:
        if self._cached is not None or self._seen_done:
            return True
        if self.store.backend.exists(self.task.result_key):
            self._seen_done = True
            return True
        return False

    def peek(self) -> Optional[TaskResult]:
        if self._cached is None and self.done():
            self._cached = self.store.get(self.task.result_key)
        return self._cached

    def _unwrap(self, res: TaskResult) -> Any:
        if not res.success:
            raise RuntimeError(
                f"task {self.task.task_id} failed after attempt {res.attempt}:\n{res.error}"
            )
        return res.value

    def result(self, timeout_s: float = 120.0, poll_s: Optional[float] = None) -> Any:
        try:
            self.store.wait_keys(
                [self.task.result_key], timeout_s=timeout_s, poll_s=poll_s
            )
        except TimeoutError:
            raise TimeoutError(
                f"task {self.task.task_id} not done in {timeout_s}s"
            ) from None
        res = self.peek()
        assert res is not None
        return self._unwrap(res)

    def errors(self) -> List[TaskResult]:
        """All published failed attempts (for diagnostics), fetched in one
        batched round-trip."""
        keys = self.store.backend.list(self.task.result_key + ".err")
        got = self.store.get_many(keys, worker="driver")
        return [got[k] for k in keys if k in got]


def wait(
    futures: Sequence[ResultFuture],
    return_when: str = ALL_COMPLETED,
    timeout_s: float = 120.0,
    poll_s: Optional[float] = None,
) -> Tuple[List[ResultFuture], List[ResultFuture]]:
    """PyWren-style wait: returns (done, not_done).  Blocks on the store's
    put notifications, so a completing task re-evaluates the condition
    immediately instead of after a poll interval.  Purely event-driven for
    in-process backends; cross-process backends re-check on the store's
    fallback tick (see ``ObjectStore.watch_tick_s``).

    Each wake re-checks only the still-pending futures, in ONE batched
    existence probe per store handle (``ObjectStore.exists_many``) — a
    completion burst over an N-task map costs O(N) probes total, not
    O(N²) per-key stats (a real round-trip each on a file/network
    backend).  Doneness is sticky on the future (publishes are
    ``if_absent``), so nothing already seen done is ever probed again."""
    deadline = time.monotonic() + timeout_s
    store = futures[0].store if futures else None
    backends = {id(f.store.backend) for f in futures}
    if len(backends) > 1:
        # Watch state is per *backend*; we can only block on one backend's
        # condition, and completions landing in the others never advance
        # its sequence — a fallback re-check tick is required for liveness.
        # (Distinct store handles over one shared backend stay event-driven.)
        tick = WATCH_FALLBACK_TICK_S if poll_s is None else poll_s
    else:
        tick = store.watch_tick_s(poll_s) if store is not None else poll_s
    pending = [f for f in futures if not (f._cached is not None or f._seen_done)]
    seq: Optional[int] = None
    single_store = len({id(f.store) for f in futures}) <= 1 and len(backends) <= 1
    while True:
        landed = None
        if store is not None and single_store and tick is None and seq is not None:
            # Incremental: recent put events name their keys, so pending
            # futures retire with no backend probe at all (puts_since).
            seq, landed = store.puts_since(seq)
        elif store is not None:
            seq = store.put_seq()
        by_store: dict = {}
        for f in pending:
            by_store.setdefault(id(f.store), (f.store, []))[1].append(f)
        still = []
        for st, group in by_store.values():
            if landed is not None:
                present = landed
            else:
                present = st.exists_many(
                    [f.result_key for f in group], worker="driver"
                )
            for f in group:
                if f.result_key in present:
                    f._seen_done = True
                else:
                    still.append(f)
        pending = still
        if (
            return_when == ALWAYS
            or (return_when == ANY_COMPLETED and len(pending) < len(futures))
            or (return_when == ALL_COMPLETED and not pending)
        ):
            done = [f for f in futures if f._cached is not None or f._seen_done]
            not_done = [f for f in futures if not (f._cached is not None or f._seen_done)]
            return done, not_done
        now = time.monotonic()
        if now > deadline:
            raise TimeoutError(
                f"wait timed out with {len(pending)}/{len(futures)} pending"
            )
        remaining = deadline - now
        if store is not None:
            if tick is None:
                store.wait_put(seq, remaining)
            else:
                store.fallback_tick_waits += 1
                store.wait_put(seq, min(tick, remaining))
        else:
            # reprolint: disable=EVENT001(no store handle to watch in the storeless path; bounded fallback tick)
            time.sleep(min(tick or 0.05, remaining))


def get_all(futures: Sequence[ResultFuture], timeout_s: float = 120.0) -> List[Any]:
    """Resolve every future; results in submission order.

    Batched: after the barrier, all uncached results are fetched in one
    ``get_many`` per store handle — the whole fan-in costs one amortized
    round-trip instead of one modeled request per future (the numpywren
    multi-get lesson; dominant for large maps)."""
    wait(futures, ALL_COMPLETED, timeout_s=timeout_s)
    by_store: dict = {}
    for f in futures:
        if f._cached is None:
            by_store.setdefault(id(f.store), (f.store, []))[1].append(f)
    for store, group in by_store.values():
        try:
            fetched = store.get_many(
                [f.result_key for f in group], worker="driver", missing="error"
            )
        except KeyError as e:
            # A result that passed the completion barrier and then vanished
            # means the job was GC'd underneath us — the signature of a
            # zombie driver racing its adopter's finish_job.  Surface the
            # adoption story instead of a bare missing-key error.
            raise RuntimeError(
                f"result {e.args[0]!r} disappeared after completing: the job "
                "was finished (GC'd) by another driver — this handle's lease "
                "was likely adopted after a presumed crash"
            ) from e
        for f in group:
            f._cached = fetched[f.result_key]
    return [f._unwrap(f._cached) for f in futures]
