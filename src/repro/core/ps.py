"""Parameter server on the KV store (paper §3.3 'Parameter Servers').

'We can implement HOGWILD! stochastic gradient descent by having each
function compute the gradients based on the latest version of shared model.
Since the only coordination across functions happens through the parameter
server, such applications fit very well into the stateless function model.'

Design:
  * the model is split into **blocks** (the paper's 'range updates'), each a
    KV key, sharded across KV shards;
  * workers ``pull()`` the latest blocks, compute a gradient on their datum,
    and ``push()`` deltas via server-side ``eval`` — atomic per block, no
    global lock: HOGWILD! semantics;
  * optional **staleness bound** (the paper's 'flexible consistency
    models'): a version counter per block; pushes older than ``max_staleness``
    versions are rejected and the worker re-pulls;
  * optional int8 **gradient compression** with stochastic rounding — a
    beyond-paper distributed-optimization trick (bytes through the KV store
    are the PS bottleneck, as Fig 4 quantifies);
  * **batched pulls** — ``pull()`` fetches every block and version counter
    in one ``KVStore.mget`` (one amortized round-trip per KV shard touched,
    not one per block), and ``wait_fresh()`` lets a staleness-rejected
    worker block on the version key's *shard condition* until another
    worker's push advances it — no re-pull spinning;
  * **batched pushes** — ``push_delta()`` is the write-side mirror: the
    staleness check reads all version counters in one ``mget``, then all
    block updates ride one ``KVStore.eval_many`` and all version bumps a
    second (at most two round-trips per KV shard touched, instead of
    2·num_blocks synchronous writes; data lands strictly before versions
    so a ``wait_fresh`` reader can never observe a version ahead of its
    block).  Per-block atomicity is preserved — each update still applies
    under its shard lock — so HOGWILD! semantics are unchanged; only the
    wire cost collapses.
"""

from __future__ import annotations

import time
import uuid
from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.storage import KVStore

from .futures import get_all
from .wren import WrenExecutor


def _quantize_int8(arr: np.ndarray, rng: np.random.Generator) -> Tuple[np.ndarray, float]:
    scale = float(np.max(np.abs(arr))) / 127.0 if arr.size else 1.0
    if scale == 0.0:
        scale = 1.0
    scaled = arr / scale
    low = np.floor(scaled)
    frac = scaled - low
    q = low + (rng.random(arr.shape) < frac)  # stochastic rounding
    return np.clip(q, -127, 127).astype(np.int8), scale


def _dequantize_int8(q: np.ndarray, scale: float) -> np.ndarray:
    return q.astype(np.float32) * scale


@dataclass
class PSConfig:
    num_blocks: int = 8
    max_staleness: Optional[int] = None  # None = fully async (HOGWILD!)
    compress_int8: bool = False


class ParameterServer:
    """Blocked parameter server over a KVStore."""

    def __init__(self, kv: KVStore, params: np.ndarray, config: PSConfig, name: str = "ps") -> None:
        self.kv = kv
        self.config = config
        self.name = f"{name}-{uuid.uuid4().hex[:6]}"
        self.dim = int(params.size)
        self.block_slices = self._make_blocks(self.dim, config.num_blocks)
        # One batched write seeds all blocks + version counters (one
        # round-trip per shard, not 2·num_blocks sets).
        init: "dict" = {}
        for b, sl in enumerate(self.block_slices):
            init[self._bkey(b)] = params[sl].copy()
            init[self._vkey(b)] = 0
        self.kv.mset(init, worker="ps-init")

    @staticmethod
    def _make_blocks(dim: int, n: int) -> List[slice]:
        edges = np.linspace(0, dim, n + 1).astype(int)
        return [slice(int(a), int(b)) for a, b in zip(edges[:-1], edges[1:])]

    def _bkey(self, b: int) -> str:
        return f"{self.name}/block/{b}"

    def _vkey(self, b: int) -> str:
        return f"{self.name}/ver/{b}"

    # ---- client ops ------------------------------------------------------
    def pull(self, worker: str = "-") -> Tuple[np.ndarray, List[int]]:
        """Fetch all blocks + version counters in one batched ``mget`` —
        one amortized round-trip per KV shard instead of 2·num_blocks
        synchronous gets (the Fig 4 latency, paid once per shard)."""
        n = len(self.block_slices)
        keys = [self._bkey(b) for b in range(n)] + [self._vkey(b) for b in range(n)]
        vals = self.kv.mget(keys, worker=worker)
        parts = vals[:n]
        vers = [int(v) if v is not None else 0 for v in vals[n:]]
        return np.concatenate(parts), vers

    def wait_fresh(
        self, block: int, seen_version: int, timeout_s: float = 5.0, worker: str = "-"
    ) -> int:
        """Block until ``block``'s version advances past ``seen_version``
        (another worker pushed), waiting on the version key's shard
        condition — woken by the push itself, no polling.  Returns the
        current version (which may still equal ``seen_version`` on
        timeout)."""
        vkey = self._vkey(block)
        deadline = time.monotonic() + timeout_s
        while True:
            seq = self.kv.shard_seq(vkey)
            # reprolint: disable=BATCH001(single-key recheck between shard-condition waits; there is no fan-out to batch)
            ver = int(self.kv.get(vkey, 0, worker=worker))
            if ver > seen_version:
                return ver
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return ver
            self.kv.wait_key(vkey, seq, remaining)

    def push_delta(
        self,
        delta: np.ndarray,
        pulled_versions: Optional[List[int]] = None,
        worker: str = "-",
        rng: Optional[np.random.Generator] = None,
    ) -> int:
        """Apply delta block-wise.  Returns number of blocks applied (blocks
        rejected for staleness are skipped — caller may re-pull).

        Batched: one ``mget`` covers the staleness check for every block,
        then all accepted block updates land in one ``eval_many`` and all
        version bumps in a second — at most two round-trips per KV shard
        instead of 2·num_blocks synchronous writes.  The two-phase order
        matters: version keys may live on different shards than their
        blocks, and publishing them together in one per-shard pass could
        bump a version *before* its block data lands — a ``wait_fresh``
        reader would then pull stale data believing it fresh.  Data first,
        versions second preserves the old eval-then-incr guarantee.  Each
        block's range update still applies atomically under its shard lock
        (HOGWILD!); batching changes the wire cost only."""
        rng = rng or np.random.default_rng(0)
        n = len(self.block_slices)
        stale: set = set()
        if self.config.max_staleness is not None and pulled_versions is not None:
            vers = self.kv.mget(
                [self._vkey(b) for b in range(n)], default=0, worker=worker
            )
            for b, cur_ver in enumerate(vers):
                if int(cur_ver or 0) - pulled_versions[b] > self.config.max_staleness:
                    stale.add(b)
        block_updates: "dict" = {}
        version_bumps: "dict" = {}
        applied = 0
        for b, sl in enumerate(self.block_slices):
            if b in stale:
                continue
            chunk = delta[sl]
            if self.config.compress_int8:
                q, scale = _quantize_int8(chunk, rng)
                chunk = _dequantize_int8(q, scale)
            # server-side range update (Redis EVAL analogue): atomic per block
            block_updates[self._bkey(b)] = lambda cur, c=chunk: cur + c
            version_bumps[self._vkey(b)] = lambda v: int(v or 0) + 1
            applied += 1
        if block_updates:
            self.kv.eval_many(block_updates, worker=worker)
            self.kv.eval_many(version_bumps, worker=worker)
        return applied

    def current(self, worker: str = "-") -> np.ndarray:
        return self.pull(worker=worker)[0]


def hogwild_sgd(
    wex: WrenExecutor,
    ps: ParameterServer,
    grad_fn: Callable[[np.ndarray, Any], np.ndarray],
    data_shards: Sequence[Any],
    *,
    steps_per_worker: int = 10,
    lr: float = 0.1,
    timeout_s: float = 300.0,
) -> np.ndarray:
    """Run HOGWILD! SGD: one stateless function per data shard, each doing
    ``steps_per_worker`` async pull→grad→push iterations."""

    def _worker_fn(arg: Tuple[int, Any]) -> float:
        wid, shard = arg
        rng = np.random.default_rng(wid)
        last = 0.0
        for _ in range(steps_per_worker):
            params, vers = ps.pull(worker=f"psw{wid}")
            g = grad_fn(params, shard)
            ps.push_delta(-lr * g, vers, worker=f"psw{wid}", rng=rng)
            last = float(np.linalg.norm(g))
        return last

    get_all(wex.map(_worker_fn, list(enumerate(data_shards))), timeout_s=timeout_s)
    return ps.current()
