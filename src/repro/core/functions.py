"""Stateless functions: serialization, identity, idempotency.

PyWren's central trick: *one* registered Lambda is reused for every user
function by shipping the pickled function + datum through S3 under globally
unique keys, then invoking the generic entry point.  We reproduce exactly
that structure:

  * ``FunctionSpec``  — the pickled callable (content-addressed in the object
    store; identical functions dedupe to one object, the paper's mitigation
    for function-registration latency and code-size limits);
  * ``TaskSpec``      — one invocation = (function key, input key, task id);
    the task id is a *deterministic* hash of function + input + job, which is
    what makes re-execution idempotent;
  * ``run_task``      — the generic container entry point: fetch code, fetch
    datum, execute, publish result atomically (first writer wins).

The result envelope carries success/exception (pickled traceback string) and
per-phase virtual timings, mirroring the paper's Table 2 phase breakdown.
"""

from __future__ import annotations

import dataclasses
import hashlib
import pickle
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import cloudpickle  # the paper's serializer [7]

from repro.storage import ObjectStore, serialization

# Bound on a warm container's deserialized-function cache (entries).
_CODE_CACHE_MAX = 32


@dataclass(frozen=True)
class FunctionSpec:
    """A content-addressed serialized callable."""

    key: str  # object-store key of the pickled callable
    name: str

    @staticmethod
    def register(store: ObjectStore, fn: Callable, *, worker: str = "-") -> "FunctionSpec":
        blob = cloudpickle.dumps(fn, protocol=pickle.HIGHEST_PROTOCOL)
        key = serialization.content_key("func", blob)
        store.put_bytes(key, blob, worker=worker, if_absent=True)
        return FunctionSpec(key=key, name=getattr(fn, "__name__", "<lambda>"))

    def load(self, store: ObjectStore, *, worker: str = "-") -> Callable:
        return pickle.loads(store.get_bytes(self.key, worker=worker))


@dataclass(frozen=True)
class TaskSpec:
    """One stateless invocation.

    ``epoch`` is the *fencing token* of the attempt holding this spec: 0 in
    the queue (no attempt owns it), assigned from the monotonically
    increasing ``sched/epoch/{task}`` counter at lease time.  Every
    authoritative mutation the attempt makes downstream — heartbeat, result
    publish, complete, release — is checked against the lease record's
    epoch, so a stale attempt (reaped as dead, preempted, or raced by a
    speculative duplicate) is rejected instead of clobbering the current
    attempt's state."""

    task_id: str
    job_id: str
    func_key: str
    func_name: str
    input_key: str
    result_key: str
    attempt: int = 0  # bumped on retry; same result_key (idempotent)
    epoch: int = 0  # fencing token of the owning attempt; 0 = unleased

    @staticmethod
    def make(
        job_id: str, func: FunctionSpec, input_key: str, index: int
    ) -> "TaskSpec":
        h = hashlib.sha256(
            f"{job_id}|{func.key}|{input_key}|{index}".encode()
        ).hexdigest()[:24]
        return TaskSpec(
            task_id=f"{job_id}/t{index:06d}-{h[:8]}",
            job_id=job_id,
            func_key=func.key,
            func_name=func.name,
            input_key=input_key,
            result_key=f"result/{job_id}/{h}",
        )

    def retry(self) -> "TaskSpec":
        return TaskSpec(
            task_id=self.task_id,
            job_id=self.job_id,
            func_key=self.func_key,
            func_name=self.func_name,
            input_key=self.input_key,
            result_key=self.result_key,
            attempt=self.attempt + 1,
            epoch=self.epoch,
        )

    def with_epoch(self, epoch: int) -> "TaskSpec":
        """The leased form of this spec, carrying its fencing token."""
        return dataclasses.replace(self, epoch=epoch)

    def unleased(self) -> "TaskSpec":
        """The queue form of this spec: no owner, epoch 0."""
        return dataclasses.replace(self, epoch=0) if self.epoch else self


@dataclass
class TaskResult:
    task_id: str
    success: bool
    value: Any = None
    error: Optional[str] = None
    phases: Dict[str, float] = field(default_factory=dict)  # virtual seconds
    worker: str = "-"
    attempt: int = 0
    # True when this attempt's result is not the visible one: its epoch was
    # stale at publish time (write suppressed — see TaskSpec.epoch) or a
    # concurrent duplicate won the if_absent publish race first.
    fenced: bool = False


def stage_input(store: ObjectStore, job_id: str, value: Any, *, worker: str = "-") -> str:
    """Place one serialized datum at a content-addressed key."""
    return store.put_content_addressed(f"input/{job_id}", value, worker=worker)


def stage_inputs(
    store: ObjectStore, job_id: str, values: "list[Any]", *, worker: str = "-"
) -> "list[str]":
    """Stage a whole map's input data in one batched write.

    Each datum still gets its own content-addressed key (identical items
    dedupe to one object, preserving ``stage_input``'s idempotency), but
    the batch lands via a single ``put_many_bytes`` — one amortized
    round-trip for N items instead of N modeled PUT requests, the driver-
    side half of the Fig 5/6 request-count fix.  Returns one key per input,
    in order."""
    keyed = [
        serialization.dumps_with_key(f"input/{job_id}", v) for v in values
    ]
    store.put_many_bytes(dict(keyed), worker=worker, if_absent=True)
    return [key for key, _ in keyed]


def run_task(
    store: ObjectStore,
    task: TaskSpec,
    *,
    worker: str = "-",
    setup_vtime: float = 0.0,
    compute_time_fn: Optional[Callable[[float], float]] = None,
    fence: Optional[Callable[[], bool]] = None,
    code_cache: Optional[Dict[str, Callable]] = None,
    input_cache: Optional[Dict[str, Any]] = None,
) -> TaskResult:
    """The generic container entry point (the single registered Lambda).

    Executes the task; returns the result envelope *and* publishes it
    atomically at ``task.result_key``.  A concurrent duplicate (speculative
    copy or lease-expired retry) publishing first simply wins; this copy's
    publish becomes a no-op — the paper's exactly-once-visibility contract.

    ``fence`` is the epoch check: called immediately before the result
    publish, and if it returns False the publish is suppressed and the
    result is marked ``fenced`` — a zombie attempt (lease reaped or
    superseded by a speculative duplicate's lease) cannot clobber the
    current attempt's result.  The fence narrows, rather than replaces, the
    ``if_absent`` first-writer-wins guard: results are deterministic, so
    the residual check-to-publish window is benign.

    ``compute_time_fn`` maps real compute seconds to virtual seconds (the
    Lambda-core calibration used by the paper-figure benchmarks).
    """
    phases: Dict[str, float] = {"setup": setup_vtime}

    ledger = store.ledger

    def _span(op: str):
        before = len(ledger.records())

        class _Ctx:
            def __enter__(self_inner):
                return self_inner

            def __exit__(self_inner, *exc):
                recs = ledger.records()[before:]
                phases[op] = phases.get(op, 0.0) + sum(
                    r.vtime_s for r in recs if r.worker == worker
                )
                return False

        return _Ctx()

    try:
        with _span("fetch_code"):
            # Warm-container code cache (paper §4: container reuse keeps the
            # deserialized function around).  Safe because func keys are
            # content-addressed and immutable — a hit is byte-identical to a
            # re-fetch, it just skips the storage round trip (and its
            # charge: a cached fetch moves no wire bytes).
            fn = code_cache.get(task.func_key) if code_cache is not None else None
            if fn is None:
                fn = pickle.loads(store.get_bytes(task.func_key, worker=worker))
                if code_cache is not None:
                    code_cache[task.func_key] = fn
                    while len(code_cache) > _CODE_CACHE_MAX:
                        code_cache.pop(next(iter(code_cache)))
        with _span("fetch_input"):
            # A worker that leased a batch prefetched all its inputs in one
            # multi-get (already charged there).  The cache holds serialized
            # bytes: deserializing here gives this task a private object, so
            # sibling tasks sharing a content-addressed input can't observe
            # each other's mutations.  Absent entries fall back to an
            # individual fetch.
            if input_cache is not None and task.input_key in input_cache:
                arg = serialization.loads(input_cache[task.input_key])
            else:
                arg = store.get(task.input_key, worker=worker)
        t0 = time.perf_counter()
        value = fn(arg)
        real_compute = time.perf_counter() - t0
        phases["compute"] = (
            compute_time_fn(real_compute) if compute_time_fn else real_compute
        )
        with _span("write_output"):
            result = TaskResult(
                task_id=task.task_id,
                success=True,
                value=value,
                phases=phases,
                worker=worker,
                attempt=task.attempt,
            )
            if fence is not None and not fence():
                result.fenced = True  # stale epoch: suppress the publish
            elif not store.publish_result(task.result_key, result, worker=worker):
                result.fenced = True  # a concurrent duplicate published first
        return result
    except Exception:  # noqa: BLE001 — a task may raise anything
        result = TaskResult(
            task_id=task.task_id,
            success=False,
            error=traceback.format_exc(),
            phases=phases,
            worker=worker,
            attempt=task.attempt,
        )
        # Failures are also published atomically, but under an attempt-scoped
        # key so a later successful attempt can still win the result key.
        store.put(
            f"{task.result_key}.err{task.attempt}", result, worker=worker, if_absent=True
        )
        return result
