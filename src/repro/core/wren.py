"""Public API: the PyWren surface.

    wex = WrenExecutor(num_workers=32)
    futures = wex.map(my_function, my_list)
    results = wren.get_all(futures)

``map`` launches one stateless function per element ("Calling map launches
as many stateless functions as there are elements in the list") and mirrors
Python's native map API.  The executor owns a control loop that reaps dead
workers' leases and speculates on stragglers until the job drains.

Multi-driver: the ``Scheduler`` is a stateless handle over the KV, so any
number of executors sharing a ``store``/``kv`` pair — across processes with
``FileBackend``/``FileKVStore`` — cooperate on one queue: every driver's
workers lease from it, every driver's control loop reaps and speculates it,
and epoch fencing (see ``core/scheduler.py``) keeps the concurrent
reap/speculate/complete transitions exactly-once.  ``examples/
multi_driver.py`` and ``tests/test_multidriver.py`` exercise exactly this.

The control loop is wakeup-driven: it blocks on the scheduler's activity
event (set by ``submit*``/``complete``/requeues) and otherwise sleeps until
``Scheduler.next_wakeup_s()`` — a deadline-based fallback tick sized to the
heartbeat interval while leases are outstanding (so lease expiry and
straggler detection are still noticed without any event) and a long idle
tick when nothing is in flight.  ``shutdown()`` signals the same event so
the loop exits without waiting out a tick.
"""

from __future__ import annotations

import os
import threading
import time
import uuid
from typing import Any, Callable, Dict, Iterable, List, Optional

from repro.storage import KVStore, ObjectStore

from . import jobs
from .executor import FaultPlan, WorkerPool
from .functions import FunctionSpec, TaskSpec, stage_inputs
from .futures import ResultFuture, get_all
from .resources import LAMBDA_2017, ResourceLimits
from .scheduler import Scheduler, SchedulerConfig


class WrenExecutor:
    def __init__(
        self,
        store: Optional[ObjectStore] = None,
        kv: Optional[KVStore] = None,
        num_workers: int = 8,
        limits: ResourceLimits = LAMBDA_2017,
        scheduler_config: Optional[SchedulerConfig] = None,
        fault_plan: Optional[FaultPlan] = None,
        compute_time_fn: Optional[Callable[[float], float]] = None,
        seed: int = 0,
    ) -> None:
        self.store = store or ObjectStore()
        self.kv = kv or KVStore(num_shards=2)
        self.scheduler = Scheduler(self.kv, self.store, scheduler_config)
        self.pool = WorkerPool(
            self.store,
            self.scheduler,
            num_workers,
            limits=limits,
            fault_plan=fault_plan,
            compute_time_fn=compute_time_fn,
            seed=seed,
        )
        # Driver identity for job-manifest leases (core/jobs.py): unique per
        # executor so a restarted process adopts its predecessor's jobs via
        # the fencing takeover path rather than silently re-owning them.
        self.driver_id = f"drv-{os.getpid()}-{uuid.uuid4().hex[:6]}"
        self._driver_mu = threading.Lock()
        self._driver_jobs: Dict[str, int] = {}  # job_id -> held term
        self._driver_hb_at = time.monotonic()
        self._control_stop = threading.Event()
        self._control = threading.Thread(target=self._control_loop, daemon=True)
        self._control.start()

    # ---- control loop: reap + speculate + driver heartbeats -------------
    def _control_loop(self) -> None:
        while not self._control_stop.is_set():
            # Clear *before* reaping: activity that lands mid-pass re-arms
            # the event and the next wait returns immediately.
            self.scheduler.clear_activity()
            try:
                self.scheduler.reap()
                self.scheduler.speculate()
                self._heartbeat_driver_leases()
            except Exception:  # noqa: BLE001 — control loop must survive
                pass
            wait_s = self.scheduler.next_wakeup_s()
            hb_due = self._driver_heartbeat_due_s()
            if hb_due is not None:
                wait_s = min(wait_s, hb_due)
            if self.scheduler.wait_activity(wait_s):
                # Coalesce activity bursts (e.g. many completions) so the
                # O(tasks) reap scan runs at a bounded rate, not per event.
                self._control_stop.wait(0.02)

    # ---- driver leases: job-manifest ownership (core/jobs.py) ------------
    def register_driver(self, job_id: str) -> Optional[int]:
        """Claim the job's driver lease for this executor.  Returns the held
        term (the fencing token adoption compares against), or ``None`` if a
        live foreign driver owns the job.  The control loop heartbeats every
        registered job until ``release_driver``/``finish_job``."""
        rec = jobs.acquire_driver(
            self.kv,
            job_id,
            self.driver_id,
            self.scheduler.config.driver_lease_timeout_s,
            worker="driver",
        )
        if rec is None or rec.get("owner") != self.driver_id:
            return None
        term = int(rec["term"])
        with self._driver_mu:
            self._driver_jobs[job_id] = term
        self.scheduler.signal_activity()  # re-time the loop's next wakeup
        return term

    def release_driver(self, job_id: str) -> bool:
        """Give up a held driver lease (the record stays, expired, so a
        later adopter still draws a higher term).  No-op for jobs this
        executor doesn't hold — safe to call on error paths."""
        with self._driver_mu:
            term = self._driver_jobs.pop(job_id, None)
        if term is None:
            return False
        return jobs.release_driver(
            self.kv, job_id, self.driver_id, term, worker="driver"
        )

    def _heartbeat_driver_leases(self) -> None:
        """Extend every held driver lease in one batched eval — rate-gated
        to a quarter of the lease timeout so the control loop's activity
        bursts don't turn heartbeats into per-event round-trips.  Jobs whose
        lease was fenced (adopted at a higher term) or GC'd are dropped from
        the registry — this driver must stop claiming them."""
        timeout_s = self.scheduler.config.driver_lease_timeout_s
        with self._driver_mu:
            owned = dict(self._driver_jobs)
            if not owned:
                return
            if time.monotonic() - self._driver_hb_at < timeout_s / 4.0:
                return
            self._driver_hb_at = time.monotonic()
        lost = jobs.heartbeat_drivers(
            self.kv, owned, self.driver_id, timeout_s, worker="driver"
        )
        if lost:
            with self._driver_mu:
                for job_id in lost:
                    # Drop only if unchanged: a re-register that raced the
                    # heartbeat holds a newer term and must stay registered.
                    if self._driver_jobs.get(job_id) == owned.get(job_id):
                        self._driver_jobs.pop(job_id, None)

    def _driver_heartbeat_due_s(self) -> Optional[float]:
        with self._driver_mu:
            if not self._driver_jobs:
                return None
            interval = self.scheduler.config.driver_lease_timeout_s / 4.0
            return max(0.0, self._driver_hb_at + interval - time.monotonic())

    # ---- the paper's API -------------------------------------------------
    def map(
        self,
        fn: Callable[[Any], Any],
        items: Iterable[Any],
        *,
        job_id: Optional[str] = None,
    ) -> List[ResultFuture]:
        """One stateless function invocation per item.

        Submission is fully batched: all inputs are staged in a single
        ``put_many`` round-trip (``stage_inputs``) and all task records hit
        the scheduler queue in one pipelined push (``submit_many``) — the
        driver pays O(1) modeled requests to launch an N-task map, not
        O(N)."""
        job = job_id or f"job-{uuid.uuid4().hex[:8]}"
        func = FunctionSpec.register(self.store, fn, worker="driver")
        input_keys = stage_inputs(self.store, job, list(items), worker="driver")
        tasks = [
            TaskSpec.make(job, func, input_key, i)
            for i, input_key in enumerate(input_keys)
        ]
        self.scheduler.submit_many(tasks)
        return [ResultFuture(self.store, t) for t in tasks]

    def call_async(self, fn: Callable[[Any], Any], arg: Any) -> ResultFuture:
        return self.map(fn, [arg])[0]

    def map_get(
        self,
        fn: Callable[[Any], Any],
        items: Iterable[Any],
        timeout_s: float = 120.0,
        *,
        gc: bool = False,
    ) -> List[Any]:
        """map + resolve all results (one batched multi-get).  With
        ``gc=True`` the job's scheduler bookkeeping and result/input objects
        are freed after resolution — the right default for fire-and-forget
        supersteps where nothing re-reads the result keys."""
        job = f"job-{uuid.uuid4().hex[:8]}"
        out = get_all(self.map(fn, items, job_id=job), timeout_s=timeout_s)
        if gc:
            self.finish_job(job)
        return out

    # ---- elasticity -----------------------------------------------------
    def scale_to(self, n: int) -> None:
        self.pool.scale_to(n)

    # ---- per-job GC -----------------------------------------------------
    def finish_job(self, job_id: str) -> int:
        """Free a completed job's scheduler state and storage keys (see
        ``Scheduler.finish_job``).  Futures of the job become unresolvable —
        call only after their results have been retrieved.  Any driver lease
        this executor holds on the job is dropped from the heartbeat registry
        first — the GC deletes the lease record, and re-heartbeating it
        would resurrect a key the tombstone just retired."""
        with self._driver_mu:
            self._driver_jobs.pop(job_id, None)
        return self.scheduler.finish_job(job_id)

    # ---- lifecycle ------------------------------------------------------
    def shutdown(self) -> None:
        self._control_stop.set()
        self.scheduler.signal_activity()  # wake the control loop to exit
        self.pool.stop_all()
        self._control.join(timeout=2.0)
        # Release still-held driver leases so successors adopt immediately
        # instead of waiting out the lease timeout.  After the join: the
        # control loop must not re-extend a lease we just expired.
        with self._driver_mu:
            held = list(self._driver_jobs.keys())
        for job_id in held:
            self.release_driver(job_id)

    def __enter__(self) -> "WrenExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
