"""Stateless scheduler handle: queue, fenced epoch leases, retries,
quantile-adaptive straggler speculation — all authoritative state in the KV.

The paper's architecture (Fig 1) has a *global scheduler* dispatching
stateless functions to containers.  We take the paper at its word: the
scheduler is not a stateful server but a **handle over the KV store** — any
number of ``Scheduler`` objects (in one process or, over
``FileKVStore``/``FileBackend``, in many) may submit, lease, reap,
speculate, and GC the *same* job concurrently, and any of them can be
restarted at any time and recover from storage, the same property the
paper demands of workers.

Epoch-fencing protocol (the exactly-once-per-attempt contract):
  * ``sched/epoch/{task}`` — a monotonically increasing counter (KV
    ``incr``), the *fencing-token generator*.  Each lease acquisition draws
    the next epoch; a release-invalidated epoch is also burned here.
  * ``sched/lease/{task}`` — the **single source of truth** for the current
    attempt: ``{worker, epoch, expires, started, attempt, spec}``.  The
    spec rides inside the record so *any* handle (including one that never
    saw the submit) can requeue or speculate the task.
  * every authoritative mutation is an epoch-compared ``eval`` (Redis
    server-side script analogue) on the lease record, atomic under the
    shard lock — machine-wide for ``FileKVStore``:
      - ``heartbeat`` extends ``expires`` only if the caller's epoch is
        current;
      - ``complete``/``release`` delete the record only if the epoch is
        current (compare-then-``DELETE`` in one eval) — a stale attempt's
        complete pushes no duration sample and frees nothing;
      - ``reap`` re-checks both epoch *and* expiry inside the eval, so a
        heartbeat landing between the scheduler's read and its delete
        keeps the lease alive;
      - the worker's **result publish** is fenced too: ``run_task`` calls
        back into :meth:`Scheduler.owns_lease` immediately before
        ``publish_result``, so a zombie (presumed-dead worker whose lease
        was reaped, or a straggler superseded by a speculative duplicate's
        lease) cannot clobber the owning attempt's result.
    Two handles racing the same transition: exactly one eval wins; the
    loser observes a mismatch and does nothing.  That is what makes
    concurrent ``reap``/``speculate`` from N drivers safe.
  * job state is KV-resident as well: ``sched/jobtasks/{job}`` (task-id
    membership, written with the submit push), ``sched/specmark/{task}``
    (``setnx`` speculation marks — two drivers cannot double-duplicate),
    and ``sched/finished/{job}`` (GC tombstones, written *before* the
    state deletes so a concurrent lease in any process observes them).

Local heaps are **rebuildable caches**, never authority: ``_try_lease``
pushes ``(expires, task_id)`` / ``(started, task_id)`` hints, and a
time-gated ``kv.scan("sched/lease/")`` (``_maybe_refresh_index``, at most
once per lease timeout) folds in leases granted through *other* handles —
so if a peer driver dies, this one's reaper picks up its expired leases.
Every hint is lazily re-validated against the KV record before acting
(extended leases are re-pushed with their real expiry; completed ones are
dropped), exactly as in PR 2 — the refactor demotes the heaps from
"indexes of my state" to "hints about shared state".

Straggler speculation (paper §3.1) is now **quantile-adaptive** by
default: a task is duplicated when its elapsed time exceeds
``max(min_speculation_age_s, speculation_k × q(speculation_quantile))``
over its job's completed-duration distribution (``sched/durations/{job}``)
— the tail quantile tracks the job's own spread instead of a static
multiple of the median, so tight distributions speculate aggressively and
naturally long-tailed ones don't thrash.  Setting the legacy
``speculation_factor`` restores the old ``factor × median`` rule
(``benchmarks/microbench.py speculation_sweep`` measures both).

Notification contract (event-driven control plane) is unchanged from PR 2:
per-shard queue watch for ``lease_batch`` (any producer's ``rpush``
through the shared KV wakes waiting workers — now including producers in
other *processes* via ``FileKVStore``'s watch thread), an in-process
activity event for the control loop, and a deadline-based
``next_wakeup_s`` fallback tick bounded by the earliest hinted lease
expiry.
"""

from __future__ import annotations

import heapq
import math
import threading
import time
from collections import deque
from dataclasses import dataclass
from functools import partial
from typing import Callable, Deque, Dict, List, Optional, Set, Tuple

from repro.storage import DELETE, KVStore, ObjectStore, kv_pure

from .functions import TaskSpec

_Q = "sched/queue"
_LEASE = "sched/lease/"
_ATTEMPTS = "sched/attempts/"
_DURATION = "sched/durations/"  # per-job list: sched/durations/<job_id>
_EPOCH = "sched/epoch/"  # fencing-token generator: sched/epoch/<task_id>
_SPECMARK = "sched/specmark/"  # speculation dedupe marks (setnx)
_FINISHED = "sched/finished/"  # per-job GC tombstones
_JOBTASKS = "sched/jobtasks/"  # per-job task-id membership list
_SPECCOUNT = "sched/speccount/"  # per-job duplicates enqueued (budget gate)
_FENCED = "sched/fenced/"  # per-job fenced-zombie completions (feedback)
_JOBMANIFEST = "sched/job/"  # job manifests + driver leases (core/jobs.py)

# Cap for an untimed lease wait; workers are woken by writes/wake_workers,
# so this only bounds how long a fully idle, never-notified wait can hold.
_UNBOUNDED_WAIT_S = 3600.0

# Finished-job tombstones cached locally before FIFO eviction (the KV
# tombstone stays authoritative; the local set only saves the exists probe).
_MAX_TOMBSTONES = 1024


# ---------------------------------------------------------------------------
# KV eval functions (hot path).  Module-level + functools.partial rather
# than closures: partials of module functions serialize by REFERENCE under
# plain pickle, so a wire-backed KVStore ships a few bytes per eval instead
# of cloudpickling a code object both ways.  Captured-dict outputs (``out``)
# ride as partial args; the eval replay contract lands their mutations on
# the caller's side exactly as a closure would.
# ---------------------------------------------------------------------------

@kv_pure
def _incr_counter(cur: object) -> int:
    return int(cur or 0) + 1


@kv_pure
def _decr_counter(cur: object) -> int:
    return int(cur or 0) - 1


@kv_pure
def _lease_install(record: dict, cur: Optional[dict]) -> dict:
    # Two handles can pop duplicate queue entries of one task concurrently;
    # the higher epoch wins the record (it fenced the lower at the epoch
    # counter), never the later writer.
    if cur is not None and int(cur.get("epoch", 0)) > record["epoch"]:
        return cur
    return record


@kv_pure
def _lease_drop(
    epoch: int,
    require_expired_before: Optional[float],
    out: dict,
    cur: Optional[dict],
):
    if cur is None:
        return DELETE  # nothing to drop (key untouched)
    if epoch and int(cur.get("epoch", 0)) != epoch:
        return cur  # fenced: a different attempt owns the task
    if require_expired_before is not None and cur["expires"] > require_expired_before:
        return cur  # extended in the meantime: not reapable
    out["rec"] = cur
    return DELETE


@kv_pure
def _lease_extend(epoch: int, expires: float, out: dict, cur: Optional[dict]):
    if cur is None:
        return DELETE  # no record: leave the key absent
    if epoch and int(cur.get("epoch", 0)) != epoch:
        return cur  # fenced
    cur = dict(cur)
    cur["expires"] = expires
    out["ok"] = True
    return cur


@kv_pure
def _fenced_decay(decay: float, v: object):
    cur = float(v or 0) - decay
    return cur if cur > 1e-9 else DELETE


@kv_pure
def _probe_keep(out: dict, cur):
    # Read-only probe riding an eval_many batch: reports the stored value
    # without changing presence (DELETE on an absent key is a no-op pop, so
    # the key stays absent; a present value is stored back unchanged).
    if cur is None:
        return DELETE
    out["rec"] = cur
    return cur


def quantile(samples: List[float], q: float) -> float:
    """Upper empirical quantile (nearest-rank): smallest sample with at
    least ``q`` of the distribution at or below it."""
    s = sorted(samples)
    rank = min(len(s) - 1, max(0, math.ceil(q * len(s)) - 1))
    return s[rank]


@dataclass
class SchedulerConfig:
    """Knobs for leases, retries, and straggler speculation.

    Speculation threshold (elapsed time before a running task gets a
    duplicate enqueued):

      * default (``speculation_factor=None``): the quantile rule
        ``max(min_speculation_age_s, speculation_k × q(speculation_quantile))``
        over the job's completed durations — adaptive to each job's own
        distribution;
      * legacy (``speculation_factor=f``): ``max(min_age, f × median)``,
        the static PR-1/2 rule, kept for comparability and for the
        microbench sweep.

    ``min_speculation_age_s`` floors both rules: with no-op tasks the
    distribution is microseconds wide and a millisecond-scale threshold
    would duplicate any task that merely hit a scheduler blip.

    The duplicate *budget* (``speculation_budget_frac``) caps how many
    duplicates one job may ever enqueue — ``max(1, frac × job size)`` —
    across every driver (the count is a shared KV counter), so a sick job
    cannot turn the cluster into a duplicate factory.  And fenced zombies
    feed back: every attempt whose completion was fenced (it had been
    reaped or superseded while actually still running) multiplies the
    job's threshold by ``(1 + speculation_zombie_backoff × count)`` — a
    job that keeps producing zombies was speculating on tasks that were
    *alive*, so its threshold was too tight, and backing it off stops the
    thrash.

    The backoff also *heals*: each subsequent completion that wins its
    fence un-fenced decays the job's zombie counter by
    ``speculation_zombie_decay`` (deleting the key at zero), so a
    transient blip — one slow heartbeat that fenced a batch of live
    attempts — doesn't suppress speculation for the rest of a long job.
    Set the decay to 0 to keep the counter sticky (the pre-decay
    behavior).
    """

    lease_timeout_s: float = 1.0
    max_attempts: int = 4
    speculation_factor: Optional[float] = None
    speculation_quantile: float = 0.95
    speculation_k: float = 1.5
    min_completed_for_speculation: int = 5
    min_speculation_age_s: float = 0.05
    speculation_budget_frac: float = 0.10
    speculation_zombie_backoff: float = 1.0
    speculation_zombie_decay: float = 1.0
    heartbeat_interval_s: float = 0.2
    idle_tick_s: float = 0.5  # control-loop fallback when no work in flight
    # Job-manifest driver lease (sched/job/{job}/driver): how long a job
    # survives without a driver heartbeat before adopters may take over.
    # Must comfortably exceed the control-loop cadence; the executor
    # heartbeats registered jobs at most every driver_lease_timeout_s / 4.
    driver_lease_timeout_s: float = 2.0

    def straggler_threshold_s(self, durations: List[float], fenced: int = 0) -> float:
        if self.speculation_factor is not None:
            base = self.speculation_factor * quantile(durations, 0.5)
        else:
            base = self.speculation_k * quantile(durations, self.speculation_quantile)
        backoff = 1.0 + self.speculation_zombie_backoff * max(0, fenced)
        return max(base, self.min_speculation_age_s) * backoff

    def speculation_budget(self, n_tasks: int) -> int:
        """Max duplicates a job of ``n_tasks`` may enqueue (≥ 1 so small
        jobs can still hedge one straggler)."""
        return max(1, int(self.speculation_budget_frac * n_tasks))


class Scheduler:
    """A stateless handle over shared scheduler state in the KV.

    Construct as many as you like over the same ``kv``/``store`` pair —
    including in other processes via ``FileKVStore``/``FileBackend``.  All
    mutating operations are epoch-fenced KV transactions (module
    docstring), so handles cannot corrupt each other; the in-memory fields
    below are caches and advisory counters only."""

    def __init__(
        self,
        kv: KVStore,
        store: ObjectStore,
        config: Optional[SchedulerConfig] = None,
    ) -> None:
        self.kv = kv
        self.store = store
        self.config = config or SchedulerConfig()
        self._lock = threading.Lock()
        # Spec cache (authoritative copy rides in queue entries and lease
        # records): serves pending() and avoids KV reads on requeue paths.
        self._specs: Dict[str, TaskSpec] = {}
        self._speculated: set = set()  # local mirror of sched/specmark/*
        self._jobs: Dict[str, Set[str]] = {}  # cache of sched/jobtasks/*
        # Local mirror of sched/finished/* tombstones (bounded FIFO): saves
        # the per-lease KV probe for jobs this handle already saw finish.
        self._finished_jobs: Set[str] = set()
        self._finished_order: Deque[str] = deque()
        # Jobs this handle saw fence a zombie: gates the decay eval in
        # complete() so the common zero-fenced path pays no extra KV op.
        self._fenced_hint: Set[str] = set()
        # Per-job (durations, fenced-zombie count) cache for speculate():
        # one KV read set per heartbeat interval per job, not one per
        # control-loop pass.  Entries: (read_at, durations, fenced).
        self._dur_cache: Dict[str, Tuple[float, List[float], int]] = {}
        # Lease-index caches (lazy heaps; see module docstring).  Guarded by
        # self._lock.  KV lease records remain the source of truth.
        self._lease_heap: List[Tuple[float, str]] = []  # (expires, task_id)
        self._start_heaps: Dict[str, List[Tuple[float, str]]] = {}
        self._hinted: Set[str] = set()  # task_ids with a live expiry hint
        self._last_index_refresh = 0.0
        # Event plane (in-process; see module docstring for the contract).
        self._activity_evt = threading.Event()
        # Advisory count of leases granted through *this* handle — drives
        # the control loop's fallback tick only, never correctness.
        self._active_leases = 0

    # ---- event plane ----------------------------------------------------
    def _signal_work(self) -> None:
        """Producers made the queue non-empty.  Worker wakeups already
        happened inside the queue ``rpush`` (per-shard notify); this only
        arms the control-loop activity event."""
        self._activity_evt.set()

    def wake_workers(self) -> None:
        """Wake workers blocked on the queue shard (virtual touch) so they
        re-check stop predicates."""
        self.kv.notify_key(_Q)

    def signal_activity(self) -> None:
        """Wake the control loop (used by executor shutdown too)."""
        self._activity_evt.set()

    def clear_activity(self) -> None:
        self._activity_evt.clear()

    def wait_activity(self, timeout_s: float) -> bool:
        return self._activity_evt.wait(timeout_s)

    def next_wakeup_s(self) -> float:
        """Deadline-based fallback tick for the control loop.  While leases
        are outstanding — this handle's or, via index hints, any handle's —
        sleep until the earliest hinted expiry (capped at heartbeat
        granularity so straggler detection still runs); while work is merely
        queued, heartbeat granularity; otherwise idle long."""
        now = time.monotonic()
        with self._lock:
            busy = self._active_leases > 0 or bool(self._lease_heap)
            next_expiry = self._lease_heap[0][0] if self._lease_heap else None
        if busy or self.queue_depth() > 0:
            tick = min(
                self.config.heartbeat_interval_s,
                max(self.config.lease_timeout_s / 4.0, 0.01),
            )
            if next_expiry is not None:
                tick = min(tick, max(next_expiry - now, 0.01))
            return tick
        return self.config.idle_tick_s

    # ---- submission -----------------------------------------------------
    def _index_tasks(self, tasks: List[TaskSpec]) -> None:
        with self._lock:
            for t in tasks:
                self._specs[t.task_id] = t
                self._jobs.setdefault(t.job_id, set()).add(t.task_id)

    def submit(self, task: TaskSpec) -> None:
        self.submit_many([task])

    def submit_many(self, tasks: List[TaskSpec]) -> None:
        """Batch-submit: the task list and the per-job membership index
        land in one pipelined push (``KVStore.rpush_many`` — one round-trip
        and one coalesced wakeup per shard touched).  Membership in
        ``sched/jobtasks/{job}`` is what lets *any* handle GC the job."""
        if not tasks:
            return
        self._index_tasks(tasks)
        pushes: Dict[str, List] = {_Q: [t.unleased() for t in tasks]}
        for t in tasks:
            pushes.setdefault(_JOBTASKS + t.job_id, []).append(t.task_id)
        self.kv.rpush_many(pushes, worker="scheduler")
        self._signal_work()

    # ---- fenced lease transactions --------------------------------------
    def _job_finished(self, job_id: str) -> bool:
        """Has any handle GC'd this job?  Local tombstone cache first, then
        the authoritative KV tombstone (cached on hit)."""
        with self._lock:
            if job_id in self._finished_jobs:
                return True
        if self.kv.get(_FINISHED + job_id, worker="scheduler") is None:
            return False
        self._remember_finished(job_id)
        return True

    def _jobs_finished(self, job_ids: Set[str]) -> Set[str]:
        """Batched :meth:`_job_finished`: ONE ``mget`` for every job id the
        local tombstone cache can't answer (a lease batch is per-round-trip
        sensitive on wire substrates — per-task gets were the single
        hottest op on the net backend's map path)."""
        finished: Set[str] = set()
        unknown: List[str] = []
        with self._lock:
            for j in job_ids:
                if j in self._finished_jobs:
                    finished.add(j)
                else:
                    unknown.append(j)
        if unknown:
            vals = self.kv.mget(
                [_FINISHED + j for j in unknown], worker="scheduler"
            )
            for j, v in zip(unknown, vals):
                if v is not None:
                    self._remember_finished(j)
                    finished.add(j)
        return finished

    def _remember_finished(self, job_id: str) -> None:
        with self._lock:
            if job_id not in self._finished_jobs:
                self._finished_jobs.add(job_id)
                self._finished_order.append(job_id)
                while len(self._finished_order) > _MAX_TOMBSTONES:
                    self._finished_jobs.discard(self._finished_order.popleft())

    def _fenced_drop_lease(
        self,
        task_id: str,
        epoch: int,
        worker: str,
        *,
        require_expired_before: Optional[float] = None,
    ) -> Tuple[bool, Optional[dict]]:
        """Atomically delete the lease record iff the caller's epoch is
        current (and, for reaping, iff it is still expired at the given
        instant — a heartbeat racing the reaper keeps the lease).  Epoch 0
        is the legacy unfenced wildcard.  Returns (won, record)."""
        out: Dict[str, dict] = {}
        self.kv.eval(
            _LEASE + task_id,
            partial(_lease_drop, epoch, require_expired_before, out),
            worker=worker,
        )
        rec = out.get("rec")
        if rec is not None:
            with self._lock:
                self._active_leases = max(0, self._active_leases - 1)
                self._hinted.discard(task_id)
        return rec is not None, rec

    def owns_lease(self, task: TaskSpec) -> bool:
        """Is ``task.epoch`` still the current attempt?  This is the fence
        ``run_task`` checks immediately before publishing a result."""
        rec = self.kv.get(_LEASE + task.task_id, worker="scheduler")
        if rec is None:
            return False
        return task.epoch == 0 or int(rec.get("epoch", 0)) == task.epoch

    # ---- worker protocol --------------------------------------------------
    def _try_lease(self, worker: str) -> Optional[TaskSpec]:
        """Non-blocking: pop a task and take a fenced lease, or None."""
        batch = self._try_lease_batch(worker, 1)
        return batch[0] if batch else None

    def _try_lease_batch(self, worker: str, max_n: int) -> List[TaskSpec]:
        """Non-blocking: pop up to ``max_n`` tasks and take fenced leases,
        in THREE pipelined KV round-trips per batch — ``lpop_n`` (one queue
        transaction), one ``eval_many`` drawing every attempt counter and
        fencing epoch, one ``eval_many`` installing every lease record —
        plus one batched result-existence probe.  The pre-PR-5 path paid
        four round-trips per *task*; on a file substrate each round-trip is
        a real disk transaction, so batch leasing is what keeps worker
        wake-to-running latency flat as batches widen.  Fencing semantics
        are unchanged: every lease still draws its own epoch and installs
        via the same higher-epoch-wins CAS, and a lost install race refunds
        the attempt charge exactly as before."""
        while True:
            popped: List[TaskSpec] = self.kv.lpop_n(_Q, max_n, worker=worker)
            if not popped:
                return []
            # A batch can pop two queue entries of ONE task (a straggler and
            # its speculative duplicate): one lease is enough, the extra
            # entry is simply consumed.
            seen: Set[str] = set()
            live: List[TaskSpec] = []
            gone = self._jobs_finished({t.job_id for t in popped})
            for t in popped:
                if t.task_id in seen or t.job_id in gone:
                    continue  # stale duplicate of a GC'd job: drop, don't resurrect
                seen.add(t.task_id)
                live.append(t)
            if not live:
                continue

            counters: Dict[str, Callable] = {}
            for t in live:
                counters[_ATTEMPTS + t.task_id] = _incr_counter
            for t in live:
                counters[_EPOCH + t.task_id] = _incr_counter
            res = self.kv.eval_many(counters, default=0, worker=worker)
            # Result-existence probe, for RETRIES AND DUPLICATES ONLY (one
            # batched round-trip): a first attempt (attempts == 1) cannot
            # have a published result — releases refund their charge and GC
            # tombstones drop stale entries above — so the common fresh-task
            # path skips the probe entirely.
            maybe_done = [
                t for t in live if int(res[_ATTEMPTS + t.task_id]) > 1
            ]
            done = (
                self.store.backend.exists_many([t.result_key for t in maybe_done])
                if maybe_done
                else set()
            )
            now = time.monotonic()
            expires = now + self.config.lease_timeout_s
            candidates = []
            installs: Dict[str, Callable] = {}
            for t in live:
                attempts = int(res[_ATTEMPTS + t.task_id])
                if t.result_key in done:
                    # already done (speculative duplicate became moot): undo
                    # the attempt charge — nothing will execute
                    self.kv.incr(_ATTEMPTS + t.task_id, -1, worker=worker)
                    continue
                if attempts > self.config.max_attempts:
                    # dropped; driver will surface missing-result error (the
                    # epoch drawn above is burned, which fences nothing real)
                    continue
                epoch = int(res[_EPOCH + t.task_id])
                spec = t.unleased()
                record = {
                    "worker": worker,
                    "epoch": epoch,
                    "expires": expires,
                    "started": now,
                    "attempt": attempts - 1,
                    "spec": spec,
                }

                installs[_LEASE + t.task_id] = partial(_lease_install, record)
                candidates.append((t, spec, epoch, attempts))
            leased: List[TaskSpec] = []
            if installs:
                out = self.kv.eval_many(installs, worker=worker)
                refunds = []
                for t, spec, epoch, attempts in candidates:
                    if int(out[_LEASE + t.task_id].get("epoch", 0)) != epoch:
                        # Lost the duplicate race; that attempt owns it.
                        # Undo the attempt charge — this pop executed
                        # nothing, and burned charges would let race losses
                        # push a task over max_attempts without max_attempts
                        # real executions.
                        refunds.append(t.task_id)
                        continue
                    with self._lock:
                        self._specs[t.task_id] = spec
                        self._jobs.setdefault(t.job_id, set()).add(t.task_id)
                        self._active_leases += 1
                        self._hinted.add(t.task_id)
                        heapq.heappush(self._lease_heap, (expires, t.task_id))
                        heapq.heappush(
                            self._start_heaps.setdefault(t.job_id, []),
                            (now, t.task_id),
                        )
                    won = t if attempts == 1 else t.retry()
                    leased.append(won.with_epoch(epoch))
                if refunds:
                    self.kv.eval_many(
                        {_ATTEMPTS + tid: _decr_counter for tid in refunds},
                        default=0,
                        worker=worker,
                    )
            if leased:
                return leased

    def lease_next(self, worker: str) -> Optional[TaskSpec]:
        """Atomically pop a task and take its lease (non-blocking)."""
        return self._try_lease(worker)

    def lease_batch(
        self,
        worker: str,
        max_n: int = 1,
        timeout_s: Optional[float] = None,
        should_stop: Optional[Callable[[], bool]] = None,
    ) -> List[TaskSpec]:
        """Lease up to ``max_n`` tasks, blocking on the *queue shard's* watch
        condition until at least one is available (or ``timeout_s`` elapses /
        ``should_stop`` returns True).  Any producer's ``rpush`` through the
        shared KV wakes this — other handles, and over ``FileKVStore`` other
        *processes*.  Returning an empty list means "no work" — the caller
        re-checks its own state and may call again."""
        deadline = None if timeout_s is None else time.monotonic() + timeout_s
        while True:
            batch = self._try_lease_batch(worker, max_n)
            if batch:
                return batch
            # Snapshot the shard sequence *before* checking should_stop and
            # queue emptiness: a push — or a wake_workers() stop signal,
            # which sets the stop flag *then* touches the shard — landing
            # after the snapshot advances the sequence, so the wait below
            # returns immediately instead of missing it.
            seq = self.kv.shard_seq(_Q)
            if should_stop is not None and should_stop():
                return []
            if self.kv.llen(_Q, worker=worker) == 0:
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return []
                    self.kv.wait_key(_Q, seq, remaining)
                else:
                    self.kv.wait_key(_Q, seq, _UNBOUNDED_WAIT_S)
            if should_stop is not None and should_stop():
                return []

    def release(self, task: TaskSpec, worker: str) -> None:
        """Cleanly return a leased-but-unstarted task to the queue (graceful
        worker shutdown / scale-down preemption).  Fenced: only the current
        epoch holder can hand the task back, the released epoch is burned
        (``sched/epoch`` incr) so any in-flight heartbeat or publish from it
        is rejected, and the attempt charge is undone so a preempted task is
        not penalized toward ``max_attempts``."""
        won, rec = self._fenced_drop_lease(task.task_id, task.epoch, worker)
        if not won:
            return  # reaped/completed/superseded meanwhile: nothing to return
        if self._job_finished(task.job_id):
            return  # job GC'd while leased: don't re-create attempts/queue state
        self.kv.incr(_EPOCH + task.task_id, 1, worker=worker)  # invalidate
        self.kv.incr(_ATTEMPTS + task.task_id, -1, worker=worker)
        spec = rec.get("spec") if rec else None
        self.kv.rpush(_Q, spec if spec is not None else task.unleased(), worker=worker)
        self._signal_work()

    def heartbeat(self, task: TaskSpec, worker: str) -> bool:
        """Extend the lease iff ``task.epoch`` is still current.  A zombie's
        heartbeat (reaped, released, or superseded) is rejected — it cannot
        keep a lease alive that another attempt now owns.  Returns whether
        the extension applied."""
        epoch = task.epoch
        expires = time.monotonic() + self.config.lease_timeout_s
        out: Dict[str, bool] = {}
        self.kv.eval(
            _LEASE + task.task_id,
            partial(_lease_extend, epoch, expires, out),
            worker=worker,
        )
        return bool(out.get("ok"))

    def complete(self, task: TaskSpec, worker: str, duration_s: float) -> bool:
        """Fenced completion: drop the lease iff ``task.epoch`` is current.
        Only the winning attempt's duration enters the job's straggler
        distribution — a zombie's wall time (it sat reaped or superseded)
        would poison the quantile.  Returns whether this attempt won."""
        # The lease drop and the finished-tombstone probe ride ONE
        # ``eval_many`` (one pipelined round-trip — this pair is the per-task
        # hot path, and on a wire substrate a separate tombstone get doubled
        # completion's trip count).
        out: Dict[str, dict] = {}
        probe: Dict[str, dict] = {}
        with self._lock:
            cached_finished = task.job_id in self._finished_jobs
        updates: Dict[str, Callable] = {
            _LEASE + task.task_id: partial(_lease_drop, task.epoch, None, out)
        }
        if not cached_finished:
            updates[_FINISHED + task.job_id] = partial(_probe_keep, probe)
        self.kv.eval_many(updates, worker=worker)
        won = out.get("rec") is not None
        if won:
            with self._lock:
                self._active_leases = max(0, self._active_leases - 1)
                self._hinted.discard(task.task_id)
        finished = cached_finished or probe.get("rec") is not None
        if finished and not cached_finished:
            self._remember_finished(task.job_id)
        # An in-flight duplicate finishing after its job was GC'd must not
        # re-create state finish_job just deleted: skip the duration push
        # and scrub the result/.err objects its publish re-created (the
        # result key was absent again, so its if_absent publish won).
        if finished:
            self.store.delete_prefix(task.result_key, worker=worker)
            won = False
        elif won:
            # Advisory sample: a lost entry only nudges the speculation
            # quantile, so it is not worth a blocking round trip per task.
            self.kv.rpush_nowait(_DURATION + task.job_id, duration_s, worker=worker)
            self._maybe_decay_fenced(task.job_id, worker)
        else:
            # A fenced zombie ran to completion: it was reaped or superseded
            # while actually alive.  Count it per job — the speculation rule
            # reads this back and raises the job's threshold, so a job that
            # keeps fencing zombies stops speculating (see SchedulerConfig).
            self.kv.incr(_FENCED + task.job_id, 1, worker=worker)
            with self._lock:
                self._fenced_hint.add(task.job_id)
        self._activity_evt.set()
        return won

    def _maybe_decay_fenced(self, job_id: str, worker: str) -> None:
        """Decay the job's fenced-zombie counter on a clean (won) completion
        — the backoff heals once attempts stop getting fenced while alive
        (see ``SchedulerConfig``).  Gated on having *seen* a fence for this
        job (local hint, or a nonzero count in the speculate() cache, which
        covers fences raised by other drivers) so the common zero-fenced
        path costs no extra KV round-trip per completion."""
        decay = self.config.speculation_zombie_decay
        if decay <= 0:
            return
        with self._lock:
            hinted = job_id in self._fenced_hint
            cached = self._dur_cache.get(job_id)
        if not hinted and not (cached is not None and cached[2] > 0):
            return

        new = self.kv.eval(_FENCED + job_id, partial(_fenced_decay, decay), worker=worker)
        if new is None:
            with self._lock:
                self._fenced_hint.discard(job_id)

    # ---- index cache maintenance ----------------------------------------
    def refresh_index(self) -> int:
        """Rebuild lease-index hints from the KV (`scan` over lease
        records): fold in leases granted through *other* handles — or
        before this handle existed — so reap/speculate cover them.  Safe to
        call any time; hints are always re-validated before acting.
        One scan + one batched ``mget`` for the unknown records (the PR-2
        multi-get lesson — never one round-trip per key).  Returns the
        number of new hints added."""
        keys = self.kv.scan(_LEASE, worker="scheduler")
        with self._lock:
            unknown = [k for k in keys if k[len(_LEASE):] not in self._hinted]
        if not unknown:
            return 0
        added = 0
        records = self.kv.mget(unknown, worker="scheduler")
        for key, rec in zip(unknown, records):
            if rec is None:
                continue  # consumed between the scan and the mget
            task_id = key[len(_LEASE):]
            spec = rec.get("spec")
            with self._lock:
                if task_id in self._hinted:
                    continue
                self._hinted.add(task_id)
                heapq.heappush(self._lease_heap, (rec["expires"], task_id))
                if spec is not None:
                    self._specs.setdefault(task_id, spec)
                    self._jobs.setdefault(spec.job_id, set()).add(task_id)
                    heapq.heappush(
                        self._start_heaps.setdefault(spec.job_id, []),
                        (rec["started"], task_id),
                    )
            added += 1
        return added

    def _maybe_refresh_index(self) -> None:
        """Time-gated :meth:`refresh_index` — at most one KV scan per lease
        timeout, so a control loop ticking every heartbeat doesn't turn the
        O(shards) scan into per-tick traffic."""
        now = time.monotonic()
        with self._lock:
            if now - self._last_index_refresh < self.config.lease_timeout_s:
                return
            self._last_index_refresh = now
        self.refresh_index()

    # ---- control loop -----------------------------------------------------
    def reap(self) -> int:
        """Re-enqueue tasks whose lease expired (worker death). Returns count.

        Heap-indexed with lazy re-validation (PR 2), now over *shared*
        state: the hint heap covers every handle's leases (via
        ``_maybe_refresh_index``), and the actual requeue is a fenced
        epoch+expiry CAS-delete — two drivers reaping the same lease race
        at the eval and exactly one wins the requeue."""
        n = 0
        self._maybe_refresh_index()
        now = time.monotonic()
        while True:
            with self._lock:
                if not self._lease_heap or self._lease_heap[0][0] > now:
                    break
                _, task_id = heapq.heappop(self._lease_heap)
            # reprolint: disable=BATCH001(lazy heap revalidation is inherently per-candidate: each pop's read gates the next pop)
            lease = self.kv.get(_LEASE + task_id, worker="scheduler")
            if lease is None:
                with self._lock:
                    self._hinted.discard(task_id)
                continue  # completed, released, or job GC'd — stale hint
            if lease["expires"] > now:
                # Heartbeat extended the lease after our hint was pushed.
                with self._lock:
                    heapq.heappush(self._lease_heap, (lease["expires"], task_id))
                continue
            won, rec = self._fenced_drop_lease(
                task_id,
                int(lease.get("epoch", 0)),
                "scheduler",
                require_expired_before=now,
            )
            if not won:
                # Another driver reaped it first, the worker completed, or a
                # heartbeat slipped in — re-hint if a record is still there;
                # otherwise drop the hint marker too, or refresh_index would
                # skip every future lease of this task on this handle.
                # reprolint: disable=BATCH001(per-candidate re-hint after a lost reap race; no batch exists)
                fresh = self.kv.get(_LEASE + task_id, worker="scheduler")
                with self._lock:
                    if fresh is not None:
                        heapq.heappush(self._lease_heap, (fresh["expires"], task_id))
                    else:
                        self._hinted.discard(task_id)
                continue
            spec = rec.get("spec") if rec else None
            if spec is None:
                with self._lock:
                    spec = self._specs.get(task_id)
            if (
                spec is None
                or self._job_finished(spec.job_id)
                # reprolint: disable=BATCH001(one probe per actually-expired lease, gated by the eval win above)
                or self.store.backend.exists(spec.result_key)
            ):
                continue
            # reprolint: disable=BATCH001(requeue must be visible before the next pop's revalidation; one push per won reap)
            self.kv.rpush(_Q, spec, worker="scheduler")
            self._signal_work()
            n += 1
        return n

    def speculate(self) -> int:
        """Enqueue duplicates of straggling tasks. Returns count.

        Per-job start heaps pop exactly the candidates whose elapsed time
        crossed the straggler threshold (quantile-adaptive, multiplied by
        the job's fenced-zombie backoff; see ``SchedulerConfig``).  The
        duplicate mark is a KV ``setnx`` — N drivers speculating the same
        job enqueue each straggler once — and the per-job duplicate BUDGET
        is a shared KV counter gated by an atomic ``incr``, so all drivers
        together never exceed ``speculation_budget(job size)``."""
        n = 0
        now = time.monotonic()
        with self._lock:
            job_ids = list(self._start_heaps.keys())
        for job_id in job_ids:
            with self._lock:
                # Empty heap = nothing leased for this job; prune it so a
                # long-lived executor doesn't pay an lrange+sort per *ever
                # submitted* job on every control tick (_try_lease re-creates
                # the heap on the next lease).
                if not self._start_heaps.get(job_id):
                    self._start_heaps.pop(job_id, None)
                    self._dur_cache.pop(job_id, None)  # don't leak foreign jobs
                    continue
            cached = self._dur_cache.get(job_id)
            if cached is not None and now - cached[0] < self.config.heartbeat_interval_s:
                durations, fenced = cached[1], cached[2]
            else:
                durations = self.kv.lrange(_DURATION + job_id, worker="scheduler")
                # reprolint: disable=BATCH001(time-gated cache refill: one read per heartbeat interval per job, not per tick)
                fenced = int(self.kv.get(_FENCED + job_id, 0, worker="scheduler") or 0)
                self._dur_cache[job_id] = (now, durations, fenced)
            if len(durations) < self.config.min_completed_for_speculation:
                continue
            cutoff = now - self.config.straggler_threshold_s(durations, fenced=fenced)
            budget: Optional[int] = None  # resolved lazily, on first candidate
            while True:
                with self._lock:
                    heap = self._start_heaps.get(job_id)
                    if not heap or heap[0][0] > cutoff:
                        break
                    started, task_id = heapq.heappop(heap)
                    already = task_id in self._speculated
                # reprolint: disable=BATCH001(lazy heap revalidation is inherently per-candidate: each pop's read gates the next pop)
                lease = self.kv.get(_LEASE + task_id, worker="scheduler")
                if lease is None:
                    continue  # finished or reaped; a re-lease pushes a fresh hint
                if lease["started"] > started:
                    with self._lock:
                        heapq.heappush(heap, (lease["started"], task_id))
                    continue  # stale hint from an earlier attempt
                spec = lease.get("spec")
                if spec is None or already:
                    continue
                # reprolint: disable=BATCH001(one probe per straggler candidate that survived revalidation)
                if self.store.backend.exists(spec.result_key):
                    continue
                if budget is None:
                    # Resolved once per job pass (two KV reads), on the first
                    # real candidate; within the pass the atomic incr below
                    # is the only gate — it alone is what's race-free across
                    # drivers anyway.
                    n_tasks = self.kv.llen(_JOBTASKS + job_id, worker="scheduler")
                    budget = self.config.speculation_budget(n_tasks)
                    used = int(
                        # reprolint: disable=BATCH001(resolved once per job pass, on the first real candidate only)
                        self.kv.get(_SPECCOUNT + job_id, 0, worker="scheduler") or 0
                    )
                    if used >= budget:
                        break  # job's duplicate budget spent (across all drivers)
                if not self.kv.setnx(_SPECMARK + task_id, 1, worker="scheduler"):
                    # Another driver already duplicated this straggler.
                    with self._lock:
                        self._speculated.add(task_id)
                    continue
                # The atomic incr is the budget gate across drivers: whoever
                # pushes the count past the budget undoes its own duplicate.
                if self.kv.incr(_SPECCOUNT + job_id, 1, worker="scheduler") > budget:
                    self.kv.incr(_SPECCOUNT + job_id, -1, worker="scheduler")
                    break
                with self._lock:
                    self._speculated.add(task_id)
                # reprolint: disable=BATCH001(each duplicate push is individually gated by its setnx mark and budget incr)
                self.kv.rpush(_Q, spec, worker="scheduler")
                self._signal_work()
                n += 1
        return n

    # ---- per-job GC -------------------------------------------------------
    def finish_job(self, job_id: str) -> int:
        """Free everything a completed job left behind — callable from *any*
        handle, not just the submitter, because task membership lives in
        ``sched/jobtasks/{job}``.  The KV tombstone (``sched/finished/``)
        is written **before** the deletes, so a concurrent lease in any
        process drops the job's queued duplicates instead of resurrecting
        the state being freed.  Returns the number of tasks freed.  Futures
        for the job become unresolvable (their result keys are deleted) —
        call only after results have been retrieved."""
        already = self.kv.get(_FINISHED + job_id, worker="scheduler") is not None
        self.kv.set(_FINISHED + job_id, 1, worker="scheduler")
        self._remember_finished(job_id)
        kv_ids = self.kv.lrange(_JOBTASKS + job_id, worker="scheduler")
        with self._lock:
            task_ids = set(self._jobs.pop(job_id, set()))
            task_ids.update(kv_ids)
            for tid in task_ids:
                self._specs.pop(tid, None)
                self._speculated.discard(tid)
            self._start_heaps.pop(job_id, None)
            self._dur_cache.pop(job_id, None)
        # The job's manifest keyspace (manifest/stage/barrier records and
        # the driver lease, core/jobs.py) goes behind the same tombstone —
        # and is scrubbed on EVERY call, not just the first: an adopter that
        # lost the finish race has just re-created the driver record via its
        # fencing takeover, and its own finish_job must remove it again.
        manifest_keys = self.kv.scan(_JOBMANIFEST + job_id + "/", worker="scheduler")
        if manifest_keys:
            self.kv.mdel(manifest_keys, worker="scheduler")
        if already:
            return 0  # another handle (or an earlier call) already freed it
        # Batched KV cleanup: one amortized round-trip per shard, and the
        # removed-lease count settles the advisory lease accounting that
        # per-task fenced drops would otherwise pay a get+eval per task for.
        removed = self.kv.mdel([_LEASE + tid for tid in task_ids], worker="scheduler")
        with self._lock:
            self._active_leases = max(0, self._active_leases - removed)
            self._hinted.difference_update(task_ids)
        self.kv.mdel(
            [_ATTEMPTS + tid for tid in task_ids]
            + [_EPOCH + tid for tid in task_ids]
            + [_SPECMARK + tid for tid in task_ids]
            + [_DURATION + job_id, _JOBTASKS + job_id]
            + [_SPECCOUNT + job_id, _FENCED + job_id],
            worker="scheduler",
        )
        self.store.delete_prefix(f"result/{job_id}/", worker="scheduler")
        # Trailing slash: 'input/train' must not also match job 'train2'.
        self.store.delete_prefix(f"input/{job_id}/", worker="scheduler")
        return len(task_ids)

    def pending(self) -> int:
        with self._lock:
            specs = list(self._specs.values())
        done = self.store.backend.exists_many([s.result_key for s in specs])
        return sum(1 for s in specs if s.result_key not in done)

    def queue_depth(self) -> int:
        return self.kv.llen(_Q, worker="scheduler")

    def attempts(self, task: TaskSpec) -> int:
        return int(self.kv.get(_ATTEMPTS + task.task_id, 0, worker="scheduler"))

    def epoch(self, task: TaskSpec) -> int:
        """Current fencing epoch of a task (0 = never leased)."""
        return int(self.kv.get(_EPOCH + task.task_id, 0, worker="scheduler"))
