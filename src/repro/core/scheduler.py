"""Global scheduler: queue, leases, retries, straggler speculation.

The paper's architecture (Fig 1) has a *global scheduler* dispatching
stateless functions to containers.  Scheduling state itself lives in the
low-latency KV store (we eat our own dogfood: the scheduler is a KV-store
client, not a stateful server — it can be restarted at any time and recover
from storage, the same property the paper demands of workers).

Fault tolerance model (paper §3.1):
  * a worker takes a *lease* on a task (KV ``setnx``) with an expiry;
  * while running it heartbeats (extends the lease);
  * if the worker dies, the lease expires and ``reap()`` re-enqueues the
    task; since results publish atomically, the retry is idempotent;
  * *speculation*: tasks running much longer than the completed-task median
    get a duplicate enqueued (the paper observed S3 stragglers in its word
    count; speculative copies are PyWren-safe because of first-writer-wins).

Notification contract (event-driven control plane):
  * **work condition** — every producer that makes the queue non-empty
    (``submit``/``submit_many``, ``reap`` requeues, ``speculate``
    duplicates, ``release``) notifies ``_work_cv``; workers block in
    ``lease_batch`` on that condition instead of sleeping between polls.
    The queue length is re-checked under the condition lock before every
    wait, so an in-process producer can never be missed.  A worker being
    stopped is woken via ``wake_workers()`` and re-checks its stop
    predicate.
  * **activity event** — ``submit*``/``complete``/``release`` (and any
    requeue) set ``_activity_evt`` so the executor's control loop wakes
    immediately on job progress.  Between events the control loop sleeps
    until ``next_wakeup_s()``: a deadline-based fallback tick derived from
    the heartbeat interval / lease timeout while leases are outstanding
    (so reaping and straggler detection still run on time), and a long
    idle tick when nothing is queued or leased.
  * wakeup guarantee: notifications are in-process only.  A scheduler
    restarted against the same KV store recovers from storage as before —
    the fallback tick, not the condition, is the cross-process safety net.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.storage import KVStore, ObjectStore

from .functions import TaskSpec

_Q = "sched/queue"
_LEASE = "sched/lease/"
_ATTEMPTS = "sched/attempts/"
_RUNNING = "sched/running"
_DURATION = "sched/durations"


@dataclass
class SchedulerConfig:
    lease_timeout_s: float = 1.0
    max_attempts: int = 4
    speculation_factor: float = 3.0  # duplicate tasks slower than f * median
    min_completed_for_speculation: int = 5
    heartbeat_interval_s: float = 0.2
    idle_tick_s: float = 0.5  # control-loop fallback when no work in flight


class Scheduler:
    def __init__(
        self,
        kv: KVStore,
        store: ObjectStore,
        config: Optional[SchedulerConfig] = None,
    ) -> None:
        self.kv = kv
        self.store = store
        self.config = config or SchedulerConfig()
        self._lock = threading.Lock()
        # task_id -> spec, for requeue on reap (specs are tiny; the heavy
        # payloads live behind input/func keys in the object store).
        self._specs: Dict[str, TaskSpec] = {}
        self._speculated: set = set()
        # Event plane (in-process; see module docstring for the contract).
        self._work_cv = threading.Condition()
        self._activity_evt = threading.Event()
        # Advisory count of outstanding leases — drives the control loop's
        # fallback tick only, never correctness (kv lease records stay the
        # source of truth and survive a scheduler restart).
        self._active_leases = 0

    # ---- event plane ----------------------------------------------------
    def _signal_work(self, n: int = 1) -> None:
        """Wake workers blocked in ``lease_batch``: n new queue entries."""
        with self._work_cv:
            if n == 1:
                self._work_cv.notify()
            else:
                self._work_cv.notify_all()
        self._activity_evt.set()

    def wake_workers(self) -> None:
        """Broadcast to blocked workers so they re-check stop predicates."""
        with self._work_cv:
            self._work_cv.notify_all()

    def signal_activity(self) -> None:
        """Wake the control loop (used by executor shutdown too)."""
        self._activity_evt.set()

    def clear_activity(self) -> None:
        self._activity_evt.clear()

    def wait_activity(self, timeout_s: float) -> bool:
        return self._activity_evt.wait(timeout_s)

    def next_wakeup_s(self) -> float:
        """Deadline-based fallback tick for the control loop: while leases
        are outstanding (reap/speculation deadlines pending) or work is
        queued, wake at heartbeat granularity; otherwise idle long."""
        with self._lock:
            busy = self._active_leases > 0
        if busy or self.queue_depth() > 0:
            return min(
                self.config.heartbeat_interval_s,
                max(self.config.lease_timeout_s / 4.0, 0.01),
            )
        return self.config.idle_tick_s

    # ---- submission -----------------------------------------------------
    def submit(self, task: TaskSpec) -> None:
        with self._lock:
            self._specs[task.task_id] = task
        self.kv.rpush(_Q, task, worker="scheduler")
        self._signal_work()

    def submit_many(self, tasks: List[TaskSpec]) -> None:
        with self._lock:
            for t in tasks:
                self._specs[t.task_id] = t
        self.kv.rpush(_Q, *tasks, worker="scheduler")
        self._signal_work(n=len(tasks))

    # ---- worker protocol --------------------------------------------------
    def _try_lease(self, worker: str) -> Optional[TaskSpec]:
        """Non-blocking: pop a task and take its lease, or None if empty."""
        while True:
            task: Optional[TaskSpec] = self.kv.lpop(_Q, worker=worker)
            if task is None:
                return None
            if self.store.backend.exists(task.result_key):
                continue  # already done (speculative duplicate became moot)
            attempts = self.kv.incr(_ATTEMPTS + task.task_id, 1, worker=worker)
            if attempts > self.config.max_attempts:
                continue  # dropped; driver will surface missing-result error
            now = time.monotonic()
            self.kv.set(
                _LEASE + task.task_id,
                {"worker": worker, "expires": now + self.config.lease_timeout_s,
                 "started": now, "attempt": int(attempts) - 1},
                worker=worker,
            )
            with self._lock:
                self._active_leases += 1
            return task.retry() if attempts > 1 else task

    def lease_next(self, worker: str) -> Optional[TaskSpec]:
        """Atomically pop a task and take its lease (non-blocking)."""
        return self._try_lease(worker)

    def lease_batch(
        self,
        worker: str,
        max_n: int = 1,
        timeout_s: Optional[float] = None,
        should_stop: Optional[Callable[[], bool]] = None,
    ) -> List[TaskSpec]:
        """Lease up to ``max_n`` tasks, blocking on the work condition until
        at least one is available (or ``timeout_s`` elapses / ``should_stop``
        returns True).  Batching amortizes queue lock traffic; returning an
        empty list means "no work" — the caller re-checks its own state and
        may call again."""
        deadline = None if timeout_s is None else time.monotonic() + timeout_s
        while True:
            batch: List[TaskSpec] = []
            while len(batch) < max_n:
                task = self._try_lease(worker)
                if task is None:
                    break
                batch.append(task)
            if batch:
                return batch
            with self._work_cv:
                if should_stop is not None and should_stop():
                    return []
                # Re-check under the condition lock: a producer notifies
                # while holding this lock, so either we see its push here or
                # our wait() is already registered and gets the notify.
                if self.kv.llen(_Q, worker=worker) == 0:
                    if deadline is not None:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            return []
                        self._work_cv.wait(remaining)
                    else:
                        self._work_cv.wait()
            if should_stop is not None and should_stop():
                return []

    def release(self, task: TaskSpec, worker: str) -> None:
        """Cleanly return a leased-but-unstarted task to the queue (graceful
        worker shutdown).  Undoes the attempt charge so a preempted task is
        not penalized toward ``max_attempts``."""
        self._drop_lease_record(task.task_id, worker)
        self.kv.incr(_ATTEMPTS + task.task_id, -1, worker=worker)
        with self._lock:
            spec = self._specs.get(task.task_id)
        self.kv.rpush(_Q, spec if spec is not None else task, worker=worker)
        self._signal_work()

    def heartbeat(self, task: TaskSpec, worker: str) -> None:
        def _extend(cur):
            if cur is None:
                return cur
            cur = dict(cur)
            cur["expires"] = time.monotonic() + self.config.lease_timeout_s
            return cur

        self.kv.eval(_LEASE + task.task_id, _extend, worker=worker)

    def _drop_lease_record(self, task_id: str, worker: str) -> None:
        """Delete a lease record, decrementing the advisory count only if a
        record actually existed — a reaped lease may already be gone by the
        time its (still running) worker completes, and double-decrementing
        would make ``next_wakeup_s`` fall back to the idle tick too early."""
        if self.kv.get(_LEASE + task_id, worker=worker) is not None:
            self.kv.delete(_LEASE + task_id, worker=worker)
            with self._lock:
                self._active_leases = max(0, self._active_leases - 1)

    def complete(self, task: TaskSpec, worker: str, duration_s: float) -> None:
        self._drop_lease_record(task.task_id, worker)
        self.kv.rpush(_DURATION, duration_s, worker=worker)
        self._activity_evt.set()

    # ---- control loop -----------------------------------------------------
    def reap(self) -> int:
        """Re-enqueue tasks whose lease expired (worker death). Returns count."""
        n = 0
        now = time.monotonic()
        with self._lock:
            specs = dict(self._specs)
        for task_id, spec in specs.items():
            if self.store.backend.exists(spec.result_key):
                continue
            lease = self.kv.get(_LEASE + task_id, worker="scheduler")
            if lease is not None and lease["expires"] < now:
                self._drop_lease_record(task_id, "scheduler")
                self.kv.rpush(_Q, spec, worker="scheduler")
                self._signal_work()
                n += 1
        return n

    def speculate(self) -> int:
        """Enqueue duplicates of straggling tasks. Returns count."""
        durations: List[float] = self.kv.lrange(_DURATION, worker="scheduler")
        if len(durations) < self.config.min_completed_for_speculation:
            return 0
        med = sorted(durations)[len(durations) // 2]
        threshold = max(self.config.speculation_factor * med, 1e-3)
        n = 0
        now = time.monotonic()
        with self._lock:
            specs = dict(self._specs)
        for task_id, spec in specs.items():
            if task_id in self._speculated:
                continue
            if self.store.backend.exists(spec.result_key):
                continue
            lease = self.kv.get(_LEASE + task_id, worker="scheduler")
            if lease is None:
                continue
            if now - lease["started"] > threshold:
                self._speculated.add(task_id)
                self.kv.rpush(_Q, spec, worker="scheduler")
                self._signal_work()
                n += 1
        return n

    def pending(self) -> int:
        with self._lock:
            specs = dict(self._specs)
        return sum(
            0 if self.store.backend.exists(s.result_key) else 1 for s in specs.values()
        )

    def queue_depth(self) -> int:
        return self.kv.llen(_Q, worker="scheduler")

    def attempts(self, task: TaskSpec) -> int:
        return int(self.kv.get(_ATTEMPTS + task.task_id, 0, worker="scheduler"))
