"""Global scheduler: queue, leases, retries, straggler speculation.

The paper's architecture (Fig 1) has a *global scheduler* dispatching
stateless functions to containers.  Scheduling state itself lives in the
low-latency KV store (we eat our own dogfood: the scheduler is a KV-store
client, not a stateful server — it can be restarted at any time and recover
from storage, the same property the paper demands of workers).

Fault tolerance model (paper §3.1):
  * a worker takes a *lease* on a task (KV ``setnx``) with an expiry;
  * while running it heartbeats (extends the lease);
  * if the worker dies, the lease expires and ``reap()`` re-enqueues the
    task; since results publish atomically, the retry is idempotent;
  * *speculation*: tasks running much longer than the completed-task median
    get a duplicate enqueued (the paper observed S3 stragglers in its word
    count; speculative copies are PyWren-safe because of first-writer-wins).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.storage import KVStore, ObjectStore

from .functions import TaskSpec

_Q = "sched/queue"
_LEASE = "sched/lease/"
_ATTEMPTS = "sched/attempts/"
_RUNNING = "sched/running"
_DURATION = "sched/durations"


@dataclass
class SchedulerConfig:
    lease_timeout_s: float = 1.0
    max_attempts: int = 4
    speculation_factor: float = 3.0  # duplicate tasks slower than f * median
    min_completed_for_speculation: int = 5
    heartbeat_interval_s: float = 0.2


class Scheduler:
    def __init__(
        self,
        kv: KVStore,
        store: ObjectStore,
        config: Optional[SchedulerConfig] = None,
    ) -> None:
        self.kv = kv
        self.store = store
        self.config = config or SchedulerConfig()
        self._lock = threading.Lock()
        # task_id -> spec, for requeue on reap (specs are tiny; the heavy
        # payloads live behind input/func keys in the object store).
        self._specs: Dict[str, TaskSpec] = {}
        self._speculated: set = set()

    # ---- submission -----------------------------------------------------
    def submit(self, task: TaskSpec) -> None:
        with self._lock:
            self._specs[task.task_id] = task
        self.kv.rpush(_Q, task, worker="scheduler")

    def submit_many(self, tasks: List[TaskSpec]) -> None:
        with self._lock:
            for t in tasks:
                self._specs[t.task_id] = t
        self.kv.rpush(_Q, *tasks, worker="scheduler")

    # ---- worker protocol --------------------------------------------------
    def lease_next(self, worker: str) -> Optional[TaskSpec]:
        """Atomically pop a task and take its lease."""
        while True:
            task: Optional[TaskSpec] = self.kv.lpop(_Q, worker=worker)
            if task is None:
                return None
            if self.store.backend.exists(task.result_key):
                continue  # already done (speculative duplicate became moot)
            attempts = self.kv.incr(_ATTEMPTS + task.task_id, 1, worker=worker)
            if attempts > self.config.max_attempts:
                continue  # dropped; driver will surface missing-result error
            now = time.monotonic()
            self.kv.set(
                _LEASE + task.task_id,
                {"worker": worker, "expires": now + self.config.lease_timeout_s,
                 "started": now, "attempt": int(attempts) - 1},
                worker=worker,
            )
            return task.retry() if attempts > 1 else task

    def heartbeat(self, task: TaskSpec, worker: str) -> None:
        def _extend(cur):
            if cur is None:
                return cur
            cur = dict(cur)
            cur["expires"] = time.monotonic() + self.config.lease_timeout_s
            return cur

        self.kv.eval(_LEASE + task.task_id, _extend, worker=worker)

    def complete(self, task: TaskSpec, worker: str, duration_s: float) -> None:
        self.kv.delete(_LEASE + task.task_id, worker=worker)
        self.kv.rpush(_DURATION, duration_s, worker=worker)

    # ---- control loop -----------------------------------------------------
    def reap(self) -> int:
        """Re-enqueue tasks whose lease expired (worker death). Returns count."""
        n = 0
        now = time.monotonic()
        with self._lock:
            specs = dict(self._specs)
        for task_id, spec in specs.items():
            if self.store.backend.exists(spec.result_key):
                continue
            lease = self.kv.get(_LEASE + task_id, worker="scheduler")
            if lease is not None and lease["expires"] < now:
                self.kv.delete(_LEASE + task_id, worker="scheduler")
                self.kv.rpush(_Q, spec, worker="scheduler")
                n += 1
        return n

    def speculate(self) -> int:
        """Enqueue duplicates of straggling tasks. Returns count."""
        durations: List[float] = self.kv.lrange(_DURATION, worker="scheduler")
        if len(durations) < self.config.min_completed_for_speculation:
            return 0
        med = sorted(durations)[len(durations) // 2]
        threshold = max(self.config.speculation_factor * med, 1e-3)
        n = 0
        now = time.monotonic()
        with self._lock:
            specs = dict(self._specs)
        for task_id, spec in specs.items():
            if task_id in self._speculated:
                continue
            if self.store.backend.exists(spec.result_key):
                continue
            lease = self.kv.get(_LEASE + task_id, worker="scheduler")
            if lease is None:
                continue
            if now - lease["started"] > threshold:
                self._speculated.add(task_id)
                self.kv.rpush(_Q, spec, worker="scheduler")
                n += 1
        return n

    def pending(self) -> int:
        with self._lock:
            specs = dict(self._specs)
        return sum(
            0 if self.store.backend.exists(s.result_key) else 1 for s in specs.values()
        )

    def queue_depth(self) -> int:
        return self.kv.llen(_Q, worker="scheduler")

    def attempts(self, task: TaskSpec) -> int:
        return int(self.kv.get(_ATTEMPTS + task.task_id, 0, worker="scheduler"))
