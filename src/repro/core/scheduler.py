"""Global scheduler: queue, leases, retries, straggler speculation.

The paper's architecture (Fig 1) has a *global scheduler* dispatching
stateless functions to containers.  Scheduling state itself lives in the
low-latency KV store (we eat our own dogfood: the scheduler is a KV-store
client, not a stateful server — it can be restarted at any time and recover
from storage, the same property the paper demands of workers).

Fault tolerance model (paper §3.1):
  * a worker takes a *lease* on a task (KV ``setnx``) with an expiry;
  * while running it heartbeats (extends the lease);
  * if the worker dies, the lease expires and ``reap()`` re-enqueues the
    task; since results publish atomically, the retry is idempotent;
  * *speculation*: tasks running much longer than the completed-task median
    get a duplicate enqueued (the paper observed S3 stragglers in its word
    count; speculative copies are PyWren-safe because of first-writer-wins).

Notification contract (event-driven control plane):
  * **per-shard queue watch** — workers block in ``lease_batch`` on the
    watch condition of the KV shard holding the queue key
    (``KVStore.wait_key``): every producer's push (``submit``/
    ``submit_many``, ``reap`` requeues, ``speculate`` duplicates,
    ``release``) notifies that shard as part of the write itself, so *any*
    producer sharing the KV — including a second scheduler handle — wakes
    waiting workers, not just this object.  ``submit_many`` is pipelined
    (``KVStore.rpush_many``): an N-task submit is one round-trip and one
    coalesced wakeup on the queue's shard, not N.  Queue length is re-checked
    between the shard-sequence snapshot and the wait, so an in-process
    push can never be missed.  A worker being stopped is woken via
    ``wake_workers()`` (a virtual shard touch) and re-checks its stop
    predicate.
  * **activity event** — ``submit*``/``complete``/``release`` (and any
    requeue) set ``_activity_evt`` so the executor's control loop wakes
    immediately on job progress.  Between events the control loop sleeps
    until ``next_wakeup_s()``, which reads the *lease-expiry heap*: the
    earliest outstanding expiry bounds the sleep (capped at heartbeat
    granularity so straggler detection still runs), and a long idle tick
    applies when nothing is queued or leased.
  * wakeup guarantee: notifications are in-process only.  A scheduler
    restarted against the same KV store recovers from storage as before —
    the fallback tick, not the condition, is the cross-process safety net.

Lease indexing (heap, lazy deletion):
  * ``_try_lease`` pushes ``(expires, task_id)`` on the expiry heap and
    ``(started, task_id)`` on the per-job start heap.  The KV lease record
    stays the *source of truth*; heap entries are hints.  ``reap`` pops
    only entries whose hinted expiry has passed, re-validates against the
    record (a heartbeat may have extended it — re-push with the real
    expiry; the task may have completed — drop), and requeues genuinely
    expired leases: O(log n) per expiry instead of an O(n) scan of every
    spec per control pass.  ``speculate`` pops per-job start-heap entries
    older than the straggler threshold the same way.

Per-job GC: completed jobs' specs, attempt counters, lease records,
duration samples, and result/input objects otherwise accumulate for the
life of the executor.  ``finish_job(job_id)`` frees all of them; stale
heap entries for the job are discarded lazily on their next pop.
"""

from __future__ import annotations

import heapq
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional, Set, Tuple

from repro.storage import KVStore, ObjectStore

from .functions import TaskSpec

_Q = "sched/queue"
_LEASE = "sched/lease/"
_ATTEMPTS = "sched/attempts/"
_DURATION = "sched/durations/"  # per-job list: sched/durations/<job_id>

# Cap for an untimed lease wait; workers are woken by writes/wake_workers,
# so this only bounds how long a fully idle, never-notified wait can hold.
_UNBOUNDED_WAIT_S = 3600.0

# Finished-job tombstones kept before FIFO eviction (see Scheduler.__init__).
_MAX_TOMBSTONES = 1024


@dataclass
class SchedulerConfig:
    lease_timeout_s: float = 1.0
    max_attempts: int = 4
    # Straggler knob (paper §3.1 / our microbench sweep): duplicate tasks
    # running longer than ``speculation_factor * median completed duration``.
    # Lower = more aggressive duplicates (costs work, hides stragglers
    # sooner); higher = near-zero duplicate work but long tails survive.
    # ``benchmarks/microbench.py speculation_sweep`` measures completion
    # time across factors against an injected straggler distribution.
    speculation_factor: float = 3.0
    min_completed_for_speculation: int = 5
    # Floor on the straggler threshold: with no-op tasks the median duration
    # is microseconds, and a 1 ms-scale floor speculates on any task that
    # merely hit a scheduler blip (flaky duplicates under CI load).  A task
    # must run at least this long before it can be called a straggler;
    # duplicating anything quicker costs more than it hides.
    min_speculation_age_s: float = 0.05
    heartbeat_interval_s: float = 0.2
    idle_tick_s: float = 0.5  # control-loop fallback when no work in flight


class Scheduler:
    def __init__(
        self,
        kv: KVStore,
        store: ObjectStore,
        config: Optional[SchedulerConfig] = None,
    ) -> None:
        self.kv = kv
        self.store = store
        self.config = config or SchedulerConfig()
        self._lock = threading.Lock()
        # task_id -> spec, for requeue on reap (specs are tiny; the heavy
        # payloads live behind input/func keys in the object store).
        self._specs: Dict[str, TaskSpec] = {}
        self._speculated: set = set()
        # job_id -> task_ids, so finish_job frees a job without scanning.
        self._jobs: Dict[str, Set[str]] = {}
        # Tombstones: jobs already GC'd.  A speculative duplicate or reaped
        # retry of a finished job may still sit in the queue; leasing it
        # would resurrect attempts/lease/duration state finish_job just
        # freed (and fail on the deleted input anyway), so _try_lease drops
        # tasks of tombstoned jobs instead.  Kept in-memory only: a *fresh*
        # scheduler over the same KV must still recover queued work.
        # Bounded (FIFO eviction at _MAX_TOMBSTONES): a duplicate outliving
        # that many subsequent jobs has long since drained from the queue,
        # and an unbounded set would just re-create per-job accumulation.
        self._finished_jobs: Set[str] = set()
        self._finished_order: Deque[str] = deque()
        # Lease indexes (lazy heaps; see module docstring).  Guarded by
        # self._lock.  KV lease records remain the source of truth.
        self._lease_heap: List[Tuple[float, str]] = []  # (expires, task_id)
        self._start_heaps: Dict[str, List[Tuple[float, str]]] = {}  # job -> (started, task_id)
        # Event plane (in-process; see module docstring for the contract).
        self._activity_evt = threading.Event()
        # Advisory count of outstanding leases — drives the control loop's
        # fallback tick only, never correctness (kv lease records stay the
        # source of truth and survive a scheduler restart).
        self._active_leases = 0

    # ---- event plane ----------------------------------------------------
    def _signal_work(self) -> None:
        """Producers made the queue non-empty.  Worker wakeups already
        happened inside the queue ``rpush`` (per-shard notify); this only
        arms the control-loop activity event."""
        self._activity_evt.set()

    def wake_workers(self) -> None:
        """Wake workers blocked on the queue shard (virtual touch) so they
        re-check stop predicates."""
        self.kv.notify_key(_Q)

    def signal_activity(self) -> None:
        """Wake the control loop (used by executor shutdown too)."""
        self._activity_evt.set()

    def clear_activity(self) -> None:
        self._activity_evt.clear()

    def wait_activity(self, timeout_s: float) -> bool:
        return self._activity_evt.wait(timeout_s)

    def next_wakeup_s(self) -> float:
        """Deadline-based fallback tick for the control loop.  While leases
        are outstanding, sleep until the earliest hinted expiry on the lease
        heap (capped at heartbeat granularity so straggler detection still
        runs); while work is merely queued, heartbeat granularity; otherwise
        idle long.  O(1): the heap top is the earliest deadline."""
        now = time.monotonic()
        with self._lock:
            busy = self._active_leases > 0
            next_expiry = self._lease_heap[0][0] if self._lease_heap else None
        if busy or self.queue_depth() > 0:
            tick = min(
                self.config.heartbeat_interval_s,
                max(self.config.lease_timeout_s / 4.0, 0.01),
            )
            if busy and next_expiry is not None:
                tick = min(tick, max(next_expiry - now, 0.01))
            return tick
        return self.config.idle_tick_s

    # ---- submission -----------------------------------------------------
    def _index_tasks(self, tasks: List[TaskSpec]) -> None:
        with self._lock:
            for t in tasks:
                self._specs[t.task_id] = t
                self._jobs.setdefault(t.job_id, set()).add(t.task_id)

    def submit(self, task: TaskSpec) -> None:
        self._index_tasks([task])
        self.kv.rpush(_Q, task, worker="scheduler")
        self._signal_work()

    def submit_many(self, tasks: List[TaskSpec]) -> None:
        """Batch-submit: the whole task list lands on the queue in one
        pipelined push (one round-trip, one wakeup on the queue's shard —
        ``KVStore.rpush_many`` coalesces the shard notify, so an N-task
        submit wakes blocked workers once, not N times)."""
        if not tasks:
            return
        self._index_tasks(tasks)
        self.kv.rpush_many({_Q: list(tasks)}, worker="scheduler")
        self._signal_work()

    # ---- worker protocol --------------------------------------------------
    def _try_lease(self, worker: str) -> Optional[TaskSpec]:
        """Non-blocking: pop a task and take its lease, or None if empty."""
        while True:
            task: Optional[TaskSpec] = self.kv.lpop(_Q, worker=worker)
            if task is None:
                return None
            with self._lock:
                if task.job_id in self._finished_jobs:
                    continue  # stale duplicate of a GC'd job: drop, don't resurrect
            if self.store.backend.exists(task.result_key):
                continue  # already done (speculative duplicate became moot)
            attempts = self.kv.incr(_ATTEMPTS + task.task_id, 1, worker=worker)
            if attempts > self.config.max_attempts:
                continue  # dropped; driver will surface missing-result error
            now = time.monotonic()
            expires = now + self.config.lease_timeout_s
            self.kv.set(
                _LEASE + task.task_id,
                {"worker": worker, "expires": expires,
                 "started": now, "attempt": int(attempts) - 1},
                worker=worker,
            )
            with self._lock:
                self._active_leases += 1
                heapq.heappush(self._lease_heap, (expires, task.task_id))
                heapq.heappush(
                    self._start_heaps.setdefault(task.job_id, []),
                    (now, task.task_id),
                )
            return task.retry() if attempts > 1 else task

    def lease_next(self, worker: str) -> Optional[TaskSpec]:
        """Atomically pop a task and take its lease (non-blocking)."""
        return self._try_lease(worker)

    def lease_batch(
        self,
        worker: str,
        max_n: int = 1,
        timeout_s: Optional[float] = None,
        should_stop: Optional[Callable[[], bool]] = None,
    ) -> List[TaskSpec]:
        """Lease up to ``max_n`` tasks, blocking on the *queue shard's* watch
        condition until at least one is available (or ``timeout_s`` elapses /
        ``should_stop`` returns True).  Any producer's ``rpush`` through the
        shared KV wakes this — not just producers on this scheduler object.
        Batching amortizes queue lock traffic; returning an empty list means
        "no work" — the caller re-checks its own state and may call again."""
        deadline = None if timeout_s is None else time.monotonic() + timeout_s
        while True:
            batch: List[TaskSpec] = []
            while len(batch) < max_n:
                task = self._try_lease(worker)
                if task is None:
                    break
                batch.append(task)
            if batch:
                return batch
            # Snapshot the shard sequence *before* checking should_stop and
            # queue emptiness: a push — or a wake_workers() stop signal,
            # which sets the stop flag *then* touches the shard — landing
            # after the snapshot advances the sequence, so the wait below
            # returns immediately instead of missing it.
            seq = self.kv.shard_seq(_Q)
            if should_stop is not None and should_stop():
                return []
            if self.kv.llen(_Q, worker=worker) == 0:
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return []
                    self.kv.wait_key(_Q, seq, remaining)
                else:
                    self.kv.wait_key(_Q, seq, _UNBOUNDED_WAIT_S)
            if should_stop is not None and should_stop():
                return []

    def release(self, task: TaskSpec, worker: str) -> None:
        """Cleanly return a leased-but-unstarted task to the queue (graceful
        worker shutdown).  Undoes the attempt charge so a preempted task is
        not penalized toward ``max_attempts``."""
        self._drop_lease_record(task.task_id, worker)
        with self._lock:
            finished = task.job_id in self._finished_jobs
            spec = self._specs.get(task.task_id)
        if finished:
            return  # job GC'd while leased: don't re-create attempts/queue state
        self.kv.incr(_ATTEMPTS + task.task_id, -1, worker=worker)
        self.kv.rpush(_Q, spec if spec is not None else task, worker=worker)
        self._signal_work()

    def heartbeat(self, task: TaskSpec, worker: str) -> None:
        def _extend(cur):
            if cur is None:
                return cur
            cur = dict(cur)
            cur["expires"] = time.monotonic() + self.config.lease_timeout_s
            return cur

        self.kv.eval(_LEASE + task.task_id, _extend, worker=worker)

    def _drop_lease_record(self, task_id: str, worker: str) -> None:
        """Delete a lease record, decrementing the advisory count only if a
        record actually existed — a reaped lease may already be gone by the
        time its (still running) worker completes, and double-decrementing
        would make ``next_wakeup_s`` fall back to the idle tick too early."""
        if self.kv.get(_LEASE + task_id, worker=worker) is not None:
            self.kv.delete(_LEASE + task_id, worker=worker)
            with self._lock:
                self._active_leases = max(0, self._active_leases - 1)

    def complete(self, task: TaskSpec, worker: str, duration_s: float) -> None:
        self._drop_lease_record(task.task_id, worker)
        # Durations are kept per job: stragglers are judged against their
        # own job's distribution, and finish_job can free the samples.  An
        # in-flight duplicate finishing after its job was GC'd must not
        # re-create state finish_job just deleted: skip the duration push
        # and scrub the result/.err objects its publish re-created (the
        # result key was absent again, so its if_absent publish won).
        with self._lock:
            finished = task.job_id in self._finished_jobs
        if finished:
            self.store.delete_prefix(task.result_key, worker=worker)
        else:
            self.kv.rpush(_DURATION + task.job_id, duration_s, worker=worker)
        self._activity_evt.set()

    # ---- control loop -----------------------------------------------------
    def reap(self) -> int:
        """Re-enqueue tasks whose lease expired (worker death). Returns count.

        Heap-indexed: pops only entries whose *hinted* expiry has passed,
        then re-validates against the KV lease record — extended leases are
        re-pushed with their real expiry, completed/GC'd ones are dropped.
        O(expired · log n), not an O(n) scan of every outstanding spec."""
        n = 0
        now = time.monotonic()
        while True:
            with self._lock:
                if not self._lease_heap or self._lease_heap[0][0] > now:
                    break
                _, task_id = heapq.heappop(self._lease_heap)
                spec = self._specs.get(task_id)
            lease = self.kv.get(_LEASE + task_id, worker="scheduler")
            if lease is None:
                continue  # completed, released, or job GC'd — stale hint
            if lease["expires"] > now:
                # Heartbeat extended the lease after our hint was pushed.
                with self._lock:
                    heapq.heappush(self._lease_heap, (lease["expires"], task_id))
                continue
            self._drop_lease_record(task_id, "scheduler")
            if spec is None or self.store.backend.exists(spec.result_key):
                continue
            self.kv.rpush(_Q, spec, worker="scheduler")
            self._signal_work()
            n += 1
        return n

    def speculate(self) -> int:
        """Enqueue duplicates of straggling tasks. Returns count.

        Per-job start heaps: a task becomes a speculation candidate only
        when its start time falls behind ``now - factor·median`` for its
        job, so each control pass pops exactly the candidates instead of
        scanning all running specs against every job's threshold."""
        n = 0
        now = time.monotonic()
        with self._lock:
            job_ids = list(self._start_heaps.keys())
        for job_id in job_ids:
            with self._lock:
                # Empty heap = nothing leased for this job; prune it so a
                # long-lived executor doesn't pay an lrange+sort per *ever
                # submitted* job on every control tick (_try_lease re-creates
                # the heap on the next lease).
                if not self._start_heaps.get(job_id):
                    self._start_heaps.pop(job_id, None)
                    continue
            durations: List[float] = self.kv.lrange(_DURATION + job_id, worker="scheduler")
            if len(durations) < self.config.min_completed_for_speculation:
                continue
            med = sorted(durations)[len(durations) // 2]
            threshold = max(
                self.config.speculation_factor * med,
                self.config.min_speculation_age_s,
            )
            cutoff = now - threshold
            while True:
                with self._lock:
                    heap = self._start_heaps.get(job_id)
                    if not heap or heap[0][0] > cutoff:
                        break
                    started, task_id = heapq.heappop(heap)
                    spec = self._specs.get(task_id)
                    already = task_id in self._speculated
                if spec is None or already:
                    continue  # job GC'd / duplicate already queued
                lease = self.kv.get(_LEASE + task_id, worker="scheduler")
                if lease is None:
                    continue  # finished or reaped; a re-lease pushes a fresh hint
                if lease["started"] > started:
                    with self._lock:
                        heapq.heappush(heap, (lease["started"], task_id))
                    continue  # stale hint from an earlier attempt
                if self.store.backend.exists(spec.result_key):
                    continue
                with self._lock:
                    self._speculated.add(task_id)
                self.kv.rpush(_Q, spec, worker="scheduler")
                self._signal_work()
                n += 1
        return n

    # ---- per-job GC -------------------------------------------------------
    def finish_job(self, job_id: str) -> int:
        """Free everything a completed job left behind: in-memory specs and
        speculation marks, per-job start heap, KV attempt counters / lease
        records / duration samples, and the job's result + staged-input
        objects.  Returns the number of tasks freed.  Futures for the job
        become unresolvable (their result keys are deleted) — call only
        after results have been retrieved.  Stale lease-heap hints are
        discarded lazily on their next pop, and queued duplicates of the
        job are dropped at lease time via the job tombstone."""
        with self._lock:
            task_ids = self._jobs.pop(job_id, set())
            for tid in task_ids:
                self._specs.pop(tid, None)
                self._speculated.discard(tid)
            self._start_heaps.pop(job_id, None)
            if job_id not in self._finished_jobs:
                self._finished_jobs.add(job_id)
                self._finished_order.append(job_id)
                while len(self._finished_order) > _MAX_TOMBSTONES:
                    self._finished_jobs.discard(self._finished_order.popleft())
        # Batched KV cleanup: one amortized round-trip per shard, and the
        # removed-lease count settles the advisory lease accounting that
        # _drop_lease_record would otherwise pay a get+delete per task for.
        removed = self.kv.mdel([_LEASE + tid for tid in task_ids], worker="scheduler")
        with self._lock:
            self._active_leases = max(0, self._active_leases - removed)
        self.kv.mdel(
            [_ATTEMPTS + tid for tid in task_ids] + [_DURATION + job_id],
            worker="scheduler",
        )
        self.store.delete_prefix(f"result/{job_id}/", worker="scheduler")
        # Trailing slash: 'input/train' must not also match job 'train2'.
        self.store.delete_prefix(f"input/{job_id}/", worker="scheduler")
        return len(task_ids)

    def pending(self) -> int:
        with self._lock:
            specs = dict(self._specs)
        return sum(
            0 if self.store.backend.exists(s.result_key) else 1 for s in specs.values()
        )

    def queue_depth(self) -> int:
        return self.kv.llen(_Q, worker="scheduler")

    def attempts(self, task: TaskSpec) -> int:
        return int(self.kv.get(_ATTEMPTS + task.task_id, 0, worker="scheduler"))
