"""KV-resident job manifests: driver-crash-tolerant multi-stage jobs.

PR 4 made the *task* plane stateless — any scheduler handle can lease,
reap, speculate, and GC any task — but the *job* plane (which stages exist,
which barriers passed, what still needs submitting) lived only in the
submitting driver's Python frames.  A driver dying mid-``mapreduce`` left a
half-shuffled job nobody else could finish.  This module puts that last
piece of driver state in the KV, under ``sched/job/{job}/``:

  ============================  ==============================================
  key                           contents
  ============================  ==============================================
  ``sched/job/{j}/manifest``    ``{job, kind, meta, term}`` — job type plus
                                everything needed to re-derive the stage
                                plans (e.g. terasort's input keys and
                                partition count)
  ``sched/job/{j}/stage/{i}``   the stage plan: registered function key/name,
                                staged input keys (in task-index order), the
                                stage's scheduler job id — enough to rebuild
                                the exact ``TaskSpec`` set deterministically
  ``sched/job/{j}/barrier/{i}`` ``{outputs, term}`` — the stage's results in
                                task order, written when the barrier passes;
                                presence means "stage done, outputs final"
  ``sched/job/{j}/driver``      the driver lease: ``{owner, term, expires}``
  ============================  ==============================================

Write discipline (what reprolint FENCE001 and the runtime sanitizer
enforce for this keyspace):

  * manifest / stage / barrier records are **immutable**: every write rides
    :func:`commit_records` — one first-writer-wins ``eval_many`` per batch.
    Two drivers racing the same record (a presumed-dead submitter limping
    on next to its adopter) both proceed with the *stored* value, so they
    submit identical task sets and converge on identical barriers; the
    records carry the writer's ``term`` for observability.
  * the **driver lease** is the one mutable key, and it is term-fenced the
    same way task leases are epoch-fenced: acquisition of an expired lease
    increments ``term`` (the fencing token), heartbeats extend only while
    owner *and* term match, and release keeps the record (expired, term
    intact) so a later adopter still draws a higher term — exactly the
    scheduler's burn-the-epoch rule.  ``time.monotonic()`` expiries compare
    across processes on one machine (CLOCK_MONOTONIC), the same contract
    task leases already rely on.
  * deletion happens in exactly one place: ``Scheduler.finish_job`` scans
    ``sched/job/{job}/`` behind the job's ``sched/finished/`` tombstone —
    the blessed tombstone-then-GC path.

Adoption protocol (driven by ``bsp.adopt_job``):

  1. **detect** — :func:`wait_for_driver_expiry` blocks on the driver key's
     shard watch until the lease is absent or past its expiry (no polling:
     each heartbeat advances the shard sequence and re-arms the wait).
  2. **fence** — :func:`acquire_driver` CASes the lease to the adopter at
     ``term + 1``; the dead driver's in-flight heartbeats now fail.
  3. **replay** — the adopter re-runs the manifest: recorded barriers
     return instantly, unplanned stages are re-planned from ``meta``, and
     planned-but-unfinished stages resubmit only tasks whose result keys
     don't exist (duplicates a dying driver left queued or leased converge
     through the task plane's epoch fencing).
  4. **barrier** — each completed stage writes its barrier record before
     its scheduler state is GC'd, so a crash at any point leaves a
     resumable prefix.
"""

from __future__ import annotations

import time
from functools import partial
from typing import Any, Dict, List, Optional

from repro.storage import KVStore, kv_pure

_JOB = "sched/job/"
_FINISHED = "sched/finished/"  # the scheduler's job tombstone keyspace


def job_finished(kv: KVStore, job_id: str, *, worker: str = "driver") -> bool:
    """True once ``Scheduler.finish_job`` has tombstoned the job — the
    signal an adopter checks before fencing a lease that will never be
    heartbeated again because the job is simply *done*."""
    return kv.get(_FINISHED + job_id, worker=worker) is not None


def manifest_key(job_id: str) -> str:
    return f"{_JOB}{job_id}/manifest"


def driver_key(job_id: str) -> str:
    return f"{_JOB}{job_id}/driver"


def stage_key(job_id: str, idx: int) -> str:
    return f"{_JOB}{job_id}/stage/{idx}"


def barrier_key(job_id: str, idx: int) -> str:
    return f"{_JOB}{job_id}/barrier/{idx}"


# ---------------------------------------------------------------------------
# immutable records: manifest, stage plans, barriers
# ---------------------------------------------------------------------------

# Eval functions are module-level + functools.partial (not closures):
# partials of module functions serialize by reference under plain pickle,
# so a wire-backed KVStore ships a few bytes per eval instead of
# cloudpickling code objects both ways (see repro.storage.net_kv).

@kv_pure
def _first_writer_fn(value: Any, cur: Any) -> Any:
    return value if cur is None else cur


def _first_writer(value: Any):
    return partial(_first_writer_fn, value)


@kv_pure
def _driver_take(owner: str, timeout_s: float, now: float, cur: Optional[dict]) -> dict:
    if cur is None:
        return {"owner": owner, "term": 1, "expires": now + timeout_s}
    if cur.get("owner") == owner:
        rec = dict(cur)
        rec["expires"] = now + timeout_s
        return rec
    if float(cur.get("expires", 0.0)) <= now:
        return {
            "owner": owner,
            "term": int(cur.get("term", 0)) + 1,
            "expires": now + timeout_s,
        }
    return cur  # live foreign driver keeps it


@kv_pure
def _driver_extend(
    owner: str, term: int, expires: float, extended: dict, job_id: str,
    cur: Optional[dict],
):
    if cur is None:
        return None  # job GC'd: leave the key absent
    if cur.get("owner") != owner or int(cur.get("term", 0)) != term:
        return cur  # fenced: an adopter holds a higher term
    rec = dict(cur)
    rec["expires"] = expires
    extended[job_id] = True
    return rec


@kv_pure
def _driver_release(owner: str, term: int, out: dict, cur: Optional[dict]):
    if cur is None:
        return None
    if cur.get("owner") != owner or int(cur.get("term", 0)) != term:
        return cur
    rec = dict(cur)
    rec["expires"] = 0.0
    out["ok"] = True
    return rec


def commit_records(
    kv: KVStore, records: Dict[str, Any], *, worker: str = "driver"
) -> Dict[str, Any]:
    """THE manifest write path: land every record in one first-writer-wins
    ``eval_many`` (one pipelined transaction round-trip per shard touched).
    Returns the *stored* value per key — callers must proceed with these,
    not their inputs, so a lost race converges instead of diverging."""
    if not records:
        return {}
    return kv.eval_many(
        {k: _first_writer(v) for k, v in records.items()}, worker=worker
    )


def read_manifest(kv: KVStore, job_id: str, *, worker: str = "driver") -> Optional[dict]:
    return kv.get(manifest_key(job_id), worker=worker)


def read_stage(kv: KVStore, job_id: str, idx: int, *, worker: str = "driver") -> Optional[dict]:
    return kv.get(stage_key(job_id, idx), worker=worker)


def read_barrier(kv: KVStore, job_id: str, idx: int, *, worker: str = "driver") -> Optional[dict]:
    return kv.get(barrier_key(job_id, idx), worker=worker)


# ---------------------------------------------------------------------------
# the driver lease (term-fenced, mirroring task-lease epoch fencing)
# ---------------------------------------------------------------------------

def acquire_driver(
    kv: KVStore,
    job_id: str,
    owner: str,
    timeout_s: float,
    *,
    worker: str = "driver",
) -> Optional[dict]:
    """Take (or extend) the job's driver lease.  One atomic eval:

      * absent            → install at term 1;
      * already ours      → extend the expiry, same term;
      * expired / released → take over at ``term + 1`` (the fence);
      * live foreign owner → no-op.

    Returns the stored record — callers check ``rec["owner"] == owner`` to
    learn whether they hold the lease (two adopters racing a takeover both
    see the single winner's record)."""
    now = time.monotonic()
    return kv.eval(
        driver_key(job_id), partial(_driver_take, owner, timeout_s, now), worker=worker
    )


def heartbeat_drivers(
    kv: KVStore,
    owned: Dict[str, int],
    owner: str,
    timeout_s: float,
    *,
    worker: str = "driver",
) -> List[str]:
    """Extend every held driver lease in ONE ``eval_many`` (the control
    loop calls this every tick; per-job evals would be per-key round-trips).
    A lease is extended only while this owner still holds the recorded term
    — a takeover (higher term) or job GC (key gone) fences the extension.
    Returns the job ids whose lease was NOT extended (lost or finished)."""
    if not owned:
        return []
    expires = time.monotonic() + timeout_s
    extended: Dict[str, bool] = {}
    updates = {
        driver_key(j): partial(_driver_extend, owner, t, expires, extended, j)
        for j, t in owned.items()
    }
    kv.eval_many(updates, worker=worker)
    return [j for j in owned if not extended.get(j)]


def release_driver(
    kv: KVStore, job_id: str, owner: str, term: int, *, worker: str = "driver"
) -> bool:
    """Give the lease up cleanly: expire the record but KEEP it (term and
    all) so the next acquisition still draws ``term + 1`` — deleting it
    would reset the term counter and let a zombie's stale term collide with
    a fresh owner's.  The record itself is removed only by the job's
    tombstoned GC (``Scheduler.finish_job``)."""
    out: Dict[str, bool] = {}
    kv.eval(driver_key(job_id), partial(_driver_release, owner, term, out), worker=worker)
    return bool(out.get("ok"))


def driver_record(kv: KVStore, job_id: str, *, worker: str = "driver") -> Optional[dict]:
    return kv.get(driver_key(job_id), worker=worker)


def _driver_state(kv: KVStore, job_id: str, worker: str) -> Optional[dict]:
    return kv.get(driver_key(job_id), worker=worker)


def wait_for_driver_expiry(
    kv: KVStore,
    job_id: str,
    timeout_s: Optional[float] = None,
    *,
    worker: str = "driver",
) -> bool:
    """Block until the job's driver lease is absent, released, or past its
    expiry — the adoption trigger.  Event-driven *and* deadline-bounded:
    each pass snapshots the driver key's shard sequence, then waits until
    the recorded expiry instant (a live driver's heartbeat advances the
    sequence and re-arms the wait; a dead driver's silence lets the wait
    run out exactly at the expiry).  Returns False only if ``timeout_s``
    elapses with the lease still live."""
    deadline = None if timeout_s is None else time.monotonic() + timeout_s
    key = driver_key(job_id)
    while True:
        seq = kv.shard_seq(key)
        rec = _driver_state(kv, job_id, worker)
        now = time.monotonic()
        if rec is None or float(rec.get("expires", 0.0)) <= now:
            return True
        wake_at = float(rec["expires"])
        if deadline is not None:
            if now >= deadline:
                return False
            wake_at = min(wake_at, deadline)
        kv.wait_key(key, seq, max(wake_at - now, 0.001) + 0.01)
