"""Decode (single-token) attention for TPU (Pallas).

The decode hot spot is memory-bound: one query row streams the whole KV
cache through VMEM.  TPU adaptation:
  * grid = (B, K_heads, S/block_k) with the cache-block dimension sequential;
    running (m, l, acc) in VMEM scratch — flash-decoding without the CUDA
    split-k reduction kernel (the sequential grid does the combine in-place);
  * all q heads of one KV group are processed together as a (group, D) tile —
    GQA turns the dot into a (group x D) @ (D x block_k) MXU matmul instead
    of `group` separate vector dots, recovering MXU utilization;
  * variable cache lengths handled by masking against `cache_len`.

For sequence-sharded caches (tp > kv_heads), `ops.decode_attention` wraps
this with a partial-softmax (m, l, acc) tree-combine over the model axis.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    cache_len_ref,  # (1,) int32 (SMEM-ish prefetch; one per batch row)
    q_ref,  # (group, D)
    k_ref,  # (block_k, D)
    v_ref,  # (block_k, D)
    o_ref,  # (group, D)
    m_scr,  # (group,)
    l_scr,  # (group,)
    acc_scr,  # (group, D)
    *,
    scale: float,
    logit_cap: Optional[float],
    window: Optional[int],
    block_k: int,
    num_k_blocks: int,
):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    clen = cache_len_ref[0]
    blk_start = kj * block_k
    # live block: overlaps [max(0, clen-window), clen)
    lo = jnp.maximum(0, clen - window) if (window is not None and window > 0) else 0
    live = jnp.logical_and(blk_start < clen, blk_start + block_k > lo)

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (group, block_k)
        if logit_cap is not None and logit_cap > 0:
            s = logit_cap * jnp.tanh(s / logit_cap)
        pos = blk_start + jax.lax.iota(jnp.int32, block_k)
        mask = pos < clen
        if window is not None and window > 0:
            mask &= pos > clen - 1 - window
        s = jnp.where(mask[None, :], s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.where(mask[None, :], jnp.exp(s - m_new[:, None]), 0.0)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p,
            v_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new

    @pl.when(kj == num_k_blocks - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jnp.ndarray,  # (B, H, D)
    k_cache: jnp.ndarray,  # (B, S, K, D)
    v_cache: jnp.ndarray,  # (B, S, K, D)
    cache_len: jnp.ndarray,  # (B,) int32
    *,
    logit_cap: Optional[float] = None,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_k: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, D = q.shape
    _, S, K, _ = k_cache.shape
    assert H % K == 0
    group = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_k = min(block_k, S)
    rem = S % block_k
    if rem:
        # Pad the cache out to a whole number of blocks.  The pad rows sit at
        # positions >= S >= cache_len, so the `pos < clen` mask already
        # excludes them — arbitrary max_len values work, no partial-block
        # kernel variant needed.
        pad = block_k - rem
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_cache = jnp.pad(k_cache, widths)
        v_cache = jnp.pad(v_cache, widths)
        S += pad
    n_k = S // block_k

    qg = q.reshape(B, K, group, D)  # group q-heads by kv head
    kt = k_cache.transpose(0, 2, 1, 3)  # (B, K, S, D)
    vt = v_cache.transpose(0, 2, 1, 3)
    clen = cache_len.astype(jnp.int32).reshape(B, 1)

    kernel = functools.partial(
        _decode_kernel,
        scale=scale,
        logit_cap=logit_cap,
        window=window,
        block_k=block_k,
        num_k_blocks=n_k,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, K, n_k),
        in_specs=[
            pl.BlockSpec((None, 1), lambda b, h, j: (b, 0)),
            pl.BlockSpec((None, None, group, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((None, None, block_k, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((None, None, block_k, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, group, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, group, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention",
    )(clen, qg.reshape(B, K, group, D), kt, vt)
    return out.reshape(B, H, D)
