"""Mamba2 SSD (state-space dual) chunked scan for TPU (Pallas).

The SSD insight (Mamba2 paper): the selective-SSM recurrence decomposes into
(a) a *within-chunk* quadratic term — plain matmuls, perfect for the MXU —
and (b) a *cross-chunk* rank-1-ish state recurrence carried sequentially.

TPU adaptation (vs the Triton kernel in the Mamba2 release):
  * grid = (B, H, n_chunks) with the chunk dimension sequential; the running
    per-head state (P x N) persists in VMEM scratch across grid steps —
    no inter-CTA synchronization needed (Triton runs a separate state-passing
    kernel; the sequential TPU grid fuses all three phases in one kernel);
  * all within-chunk ops are (chunk x chunk) / (chunk x N) / (chunk x P)
    matmuls sized to MXU tiles (chunk defaults to 128);
  * gate cumsums are computed in fp32 in-kernel (cheap VPU work) to avoid
    HBM round-trips for (B, S, H) intermediates.

Grouped B/C (G groups broadcast over H heads) is folded into index_maps.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _ssd_kernel(
    x_ref,  # (chunk, P)
    dt_ref,  # (chunk, 1)
    a_ref,  # (1, 1)  per-head A (negative)
    b_ref,  # (chunk, N)
    c_ref,  # (chunk, N)
    d_ref,  # (1, 1)  per-head skip D (or zeros)
    y_ref,  # (chunk, P) output
    state_scr,  # (P, N) carried cross-chunk state
    *,
    chunk: int,
    num_chunks: int,
    has_d: bool,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[...].astype(jnp.float32)  # (c, P)
    dt = dt_ref[...].astype(jnp.float32)[:, 0]  # (c,)
    A = a_ref[0, 0].astype(jnp.float32)
    Bm = b_ref[...].astype(jnp.float32)  # (c, N)
    Cm = c_ref[...].astype(jnp.float32)  # (c, N)

    a = A * dt  # (c,) log-decay increments
    a_cum = jnp.cumsum(a)  # inclusive
    a_total = a_cum[-1]

    # within-chunk quadratic term
    seg = a_cum[:, None] - a_cum[None, :]  # (t, s)
    tri = jax.lax.iota(jnp.int32, chunk)[:, None] >= jax.lax.iota(jnp.int32, chunk)[None, :]
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (t, s)
    scores = cb * L * dt[None, :]
    y_intra = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (t, P)

    # inter-chunk contribution from entering state
    c_decay = Cm * jnp.exp(a_cum)[:, None]  # (t, N)
    y_inter = jax.lax.dot_general(
        c_decay,
        state_scr[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (t, P)

    y = y_intra + y_inter
    if has_d:
        y = y + x * d_ref[0, 0].astype(jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)

    # state update: h' = exp(a_total) h + sum_s exp(a_total - a_cum[s]) dt_s x_s B_s^T
    w = jnp.exp(a_total - a_cum) * dt  # (s,)
    xw = x * w[:, None]  # (s, P)
    new_contrib = jax.lax.dot_general(
        xw, Bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, N)
    state_scr[...] = state_scr[...] * jnp.exp(a_total) + new_contrib


def ssd_pallas(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H)
    A: jnp.ndarray,  # (H,)
    Bmat: jnp.ndarray,  # (B, S, G, N)
    Cmat: jnp.ndarray,  # (B, S, G, N)
    D: Optional[jnp.ndarray] = None,  # (H,)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    Bz, S, H, P = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    assert H % G == 0
    rep = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    xt = x.transpose(0, 2, 1, 3)  # (B, H, S, P)
    dtt = dt.transpose(0, 2, 1)[..., None]  # (B, H, S, 1)
    bt = Bmat.transpose(0, 2, 1, 3)  # (B, G, S, N)
    ct = Cmat.transpose(0, 2, 1, 3)
    a2 = A.reshape(H, 1, 1).astype(jnp.float32)
    d2 = (D if D is not None else jnp.zeros((H,), jnp.float32)).reshape(H, 1, 1)

    kernel = functools.partial(
        _ssd_kernel, chunk=chunk, num_chunks=nc, has_d=D is not None
    )
    out = pl.pallas_call(
        kernel,
        grid=(Bz, H, nc),
        in_specs=[
            pl.BlockSpec((None, None, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, chunk, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, 1, 1), lambda b, h, c: (h, 0, 0)),
            pl.BlockSpec((None, None, chunk, N), lambda b, h, c: (b, h // rep, c, 0)),
            pl.BlockSpec((None, None, chunk, N), lambda b, h, c: (b, h // rep, c, 0)),
            pl.BlockSpec((None, 1, 1), lambda b, h, c: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bz, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="mamba2_ssd",
    )(xt, dtt, a2, bt, ct, d2)
    return out.transpose(0, 2, 1, 3)  # (B, S, H, P)
