"""Flash attention for TPU (Pallas): online-softmax blockwise attention.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * blocks are (block_q x head_dim) @ (head_dim x block_k) MXU matmuls with
    both block dims multiples of 128 (MXU systolic shape) by default;
  * the KV loop is the innermost *sequential* grid dimension; running
    (m, l, acc) state lives in VMEM scratch that persists across grid steps —
    the TPU idiom replacing CUDA's per-CTA shared-memory accumulators;
  * GQA is folded into the BlockSpec index_map (q-head h reads kv-head
    h // group) so KV heads are never materialized repeated in HBM;
  * causal + sliding-window masks are computed from program ids; fully-masked
    KV blocks are skipped via `pl.when` (no MXU work), which matters for the
    window=4096 local layers of gemma2 where >87% of blocks are masked at 32k.

Supports: causal or full, sliding window, logit softcap (gemma2), q_offset
(decode/prefill continuation).  fp32 accumulation throughout.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    # refs
    q_ref,  # (block_q, D)
    k_ref,  # (block_k, D)
    v_ref,  # (block_k, D)
    o_ref,  # (block_q, D)
    # scratch
    m_scr,  # (block_q,) running max
    l_scr,  # (block_q,) running denom
    acc_scr,  # (block_q, D) running numerator
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    logit_cap: Optional[float],
    q_offset: int,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q) + q_offset  # (bq,)
    k_pos = kj * block_k + jax.lax.iota(jnp.int32, block_k)  # (bk,)

    # block-level skip: is any (q, k) pair in this tile unmasked?
    q_lo, q_hi = qi * block_q + q_offset, qi * block_q + q_offset + block_q - 1
    k_lo, k_hi = kj * block_k, kj * block_k + block_k - 1
    live = True
    if causal:
        live = jnp.logical_and(live, k_lo <= q_hi)
    if window is not None and window > 0:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        if logit_cap is not None and logit_cap > 0:
            s = logit_cap * jnp.tanh(s / logit_cap)

        mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None and window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p,
            v_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new

    @pl.when(kj == num_k_blocks - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, K, D)
    v: jnp.ndarray,  # (B, Sk, K, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    assert H % K == 0
    group = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    n_q = Sq // block_q
    n_k = Sk // block_k

    # (B, H, S, D) layout for clean 2D blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        logit_cap=logit_cap,
        q_offset=q_offset,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=n_k,
    )

    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((None, None, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((None, None, block_k, D), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((None, None, block_k, D), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention",
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)  # back to (B, Sq, H, D)
