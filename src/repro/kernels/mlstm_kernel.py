"""mLSTM (xLSTM matrix-memory cell) parallel form for TPU (Pallas).

The stabilized parallel mLSTM is flash-attention-shaped: a lower-triangular
gate matrix D_ts = exp(F_t - F_s + i_s - m_t) replaces softmax, and the
normalizer is max(|row-sum|, exp(-m_t)) instead of the softmax denominator.
The same online-rescaling trick applies, with two twists:
  * the running stabilizer m tracks the max of the *gate* exponent (not the
    score), so it is independent of q·k and can be rescaled identically;
  * the accumulated denominator is a *signed* sum (scores are not
    exponentiated), so the final clamp uses |l|.

Gate cumsums F = cumsum(log-sigmoid f) are precomputed outside (cheap,
(B,S,H)) and streamed in per block — recomputing cross-block prefix sums
inside the kernel would serialize the parallel grid dims.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _mlstm_kernel(
    q_ref,  # (bq, D)
    k_ref,  # (bk, D)
    v_ref,  # (bk, D)
    fcum_q_ref,  # (bq, 1) F at query positions
    fcum_k_ref,  # (bk, 1) F at key positions
    i_ref,  # (bk, 1) input-gate preact at key positions
    o_ref,  # (bq, D)
    m_scr,  # (bq,)
    l_scr,  # (bq,)
    acc_scr,  # (bq, D)
    *,
    scale: float,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    live = kj * block_k <= qi * block_q + block_q - 1  # causal block skip

    @pl.when(live)
    def _compute():
        q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
        k_pos = kj * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = k_pos[None, :] <= q_pos[:, None]

        fq = fcum_q_ref[...].astype(jnp.float32)[:, 0]  # (bq,)
        fk = fcum_k_ref[...].astype(jnp.float32)[:, 0]  # (bk,)
        ig = i_ref[...].astype(jnp.float32)[:, 0]  # (bk,)
        dmat = fq[:, None] - fk[None, :] + ig[None, :]  # (bq, bk)
        dmat = jnp.where(mask, dmat, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(dmat, axis=1))
        corr = jnp.exp(m_prev - m_new)
        dexp = jnp.where(mask, jnp.exp(dmat - m_new[:, None]), 0.0)

        s = jax.lax.dot_general(
            q_ref[...].astype(jnp.float32) * scale,
            k_ref[...].astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        w = s * dexp  # signed weights
        l_scr[...] = l_scr[...] * corr + jnp.sum(w, axis=1)
        wv = jax.lax.dot_general(
            w,
            v_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * corr[:, None] + wv
        m_scr[...] = m_new

    @pl.when(kj == num_k_blocks - 1)
    def _flush():
        denom = jnp.maximum(jnp.abs(l_scr[...]), jnp.exp(-m_scr[...]))
        o_ref[...] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def mlstm_pallas(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,
    v: jnp.ndarray,
    i_gate: jnp.ndarray,  # (B, S, H)
    f_gate: jnp.ndarray,  # (B, S, H)
    *,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, S, H, D = q.shape
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    n_q, n_k = S // block_q, S // block_k

    fcum = jnp.cumsum(
        jax.nn.log_sigmoid(f_gate.astype(jnp.float32)), axis=1
    )  # (B,S,H)

    qt = q.transpose(0, 2, 1, 3)  # (B,H,S,D)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    ft = fcum.transpose(0, 2, 1)[..., None]  # (B,H,S,1)
    it = i_gate.astype(jnp.float32).transpose(0, 2, 1)[..., None]

    kernel = functools.partial(
        _mlstm_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=n_k,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((None, None, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((None, None, block_k, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((None, None, block_k, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((None, None, block_q, 1), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((None, None, block_k, 1), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((None, None, block_k, 1), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="mlstm_parallel",
    )(qt, kt, vt, ft, ft, it)
    return out.transpose(0, 2, 1, 3)
