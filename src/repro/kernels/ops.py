"""Dispatch layer: models call these; we pick the Pallas TPU kernel or a
scalable pure-JAX path.

Three tiers per op:
  * Pallas kernel (TPU target; validated in interpret mode in tests);
  * chunked jnp implementation — same blockwise algorithm in pure jnp
    (lax.scan over KV blocks carrying the online-softmax state).  This is
    what the dry-run lowers (Pallas cannot lower to the CPU backend without
    interpret mode) and what CPU smoke training runs.  Differentiable.
  * naive reference in ref.py — ground truth for tests only.

Selection: TPU backend -> Pallas; otherwise chunked jnp.  `force_ref=True`
in tests pins the naive oracle.  The env knob REPRO_FORCE_PALLAS_INTERPRET=1
exercises interpret-mode Pallas end-to-end inside models (slow; CI only).
"""

from __future__ import annotations

import math
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.util import inner_unroll

from . import ref
from .decode_attention import decode_attention_pallas
from .flash_attention import flash_attention_pallas
from .mamba2_ssd import ssd_pallas
from .mlstm_kernel import mlstm_pallas

NEG_INF = -1e30


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET") == "1":
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _attention_chunked_jnp(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, K, D)
    v: jnp.ndarray,
    *,
    causal: bool,
    window: Optional[int],
    logit_cap: Optional[float],
    q_offset: int,
    scale: float,
    block_k: int = 4096,
) -> jnp.ndarray:
    """Online-softmax attention, lax.scan over KV blocks.  Never materializes
    (Sq, Sk); peak temp is (B, H, Sq, block_k).  GQA via reshape (no repeat).
    Dv may differ from Dqk (MLA)."""
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    Dv = v.shape[-1]
    G = H // K
    block_k = min(block_k, Sk)
    # pad Sk to multiple of block
    pad = (-Sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkb = (Sk + pad) // block_k

    qg = (q * scale).reshape(B, Sq, K, G, D)
    kb = k.reshape(B, nkb, block_k, K, D)
    vb = v.reshape(B, nkb, block_k, K, Dv)
    q_pos = jnp.arange(Sq) + q_offset

    def body(carry, inp):
        m, l, acc = carry  # (B,Sq,K,G), (B,Sq,K,G), (B,Sq,K,G,D)
        kblk, vblk, jb = inp  # (B,bk,K,D), (B,bk,K,D), ()
        s = jnp.einsum("bqkgd,bskd->bqkgs", qg, kblk).astype(jnp.float32)
        if logit_cap is not None and logit_cap > 0:
            s = logit_cap * jnp.tanh(s / logit_cap)
        k_pos = jb * block_k + jnp.arange(block_k)
        mask = k_pos[None, :] < Sk  # padding
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None and window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        # optional: bf16 probabilities for the PV matmul (fp32 accumulate) —
        # halves the dominant attention activation bytes, like TPU flash
        # kernels (env REPRO_ATTN_P_BF16; a §Perf lever)
        if os.environ.get("REPRO_ATTN_P_BF16") == "1":
            pv = jnp.einsum(
                "bqkgs,bskd->bqkgd",
                p.astype(jnp.bfloat16),
                vblk.astype(jnp.bfloat16),
                preferred_element_type=jnp.float32,
            )
        else:
            pv = jnp.einsum("bqkgs,bskd->bqkgd", p, vblk.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, K, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, K, G, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, a0),
        (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nkb)),
        unroll=inner_unroll(),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
    force_ref: bool = False,
    block_k: int = 4096,
) -> jnp.ndarray:
    """(B, Sq, H, D) x (B, Sk, K, D)^2 -> (B, Sq, H, D)."""
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    if force_ref:
        return ref.mha_reference(
            q, k, v, causal=causal, window=window, logit_cap=logit_cap,
            q_offset=q_offset, scale=scale,
        )
    if (
        _use_pallas()
        and q.shape[1] % 128 == 0
        and k.shape[1] % 128 == 0
        and q.shape[-1] == v.shape[-1]  # Pallas kernel assumes Dv == Dqk
    ):
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, logit_cap=logit_cap,
            q_offset=q_offset, scale=scale, interpret=_interpret(),
        )
    if q.shape[1] * k.shape[1] <= 256 * 256:
        return ref.mha_reference(
            q, k, v, causal=causal, window=window, logit_cap=logit_cap,
            q_offset=q_offset, scale=scale,
        )
    return _attention_chunked_jnp(
        q, k, v, causal=causal, window=window, logit_cap=logit_cap,
        q_offset=q_offset, scale=scale, block_k=block_k,
    )


def decode_attention(
    q: jnp.ndarray,  # (B, H, D)
    k_cache: jnp.ndarray,  # (B, S, K, D)
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,  # (B,)
    *,
    logit_cap: Optional[float] = None,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    force_ref: bool = False,
) -> jnp.ndarray:
    """One-token attention against the KV cache.

    The jnp path is written reduction-style so that a sequence-sharded cache
    under pjit turns the softmax reductions into all-reduces (flash-decoding
    across the model axis without shard_map)."""
    if force_ref or not _use_pallas():
        return ref.decode_attention_reference(
            q, k_cache, v_cache, cache_len,
            logit_cap=logit_cap, window=window, scale=scale,
        )
    return decode_attention_pallas(
        q, k_cache, v_cache, cache_len,
        logit_cap=logit_cap, window=window, scale=scale, interpret=_interpret(),
    )


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------

def _ssd_chunked_scan_jnp(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H)
    A: jnp.ndarray,  # (H,)
    Bmat: jnp.ndarray,  # (B, S, G, N)
    Cmat: jnp.ndarray,  # (B, S, G, N)
    D: Optional[jnp.ndarray],
    *,
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,
):
    """Chunked SSD with lax.scan over chunks (state carried); peak temp is
    one chunk's (B, c, c, H) score tensor, vs the (B, nc, c, c, H) blow-up
    of the naive batched form in ref.py."""
    Bz, S, H, P = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    rep = H // G
    nc = S // chunk

    xf = x.astype(jnp.float32).reshape(Bz, nc, chunk, H, P).swapaxes(0, 1)
    dtf = dt.astype(jnp.float32).reshape(Bz, nc, chunk, H).swapaxes(0, 1)
    Bh = jnp.repeat(Bmat, rep, axis=2).astype(jnp.float32).reshape(
        Bz, nc, chunk, H, N
    ).swapaxes(0, 1)
    Ch = jnp.repeat(Cmat, rep, axis=2).astype(jnp.float32).reshape(
        Bz, nc, chunk, H, N
    ).swapaxes(0, 1)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(h, inp):
        xc, dtc, bc, cc = inp  # (B,c,H,P), (B,c,H), (B,c,H,N), (B,c,H,N)
        a = A[None, None, :] * dtc  # (B,c,H)
        a_cum = jnp.cumsum(a, axis=1)
        a_tot = a_cum[:, -1, :]  # (B,H)
        seg = a_cum[:, :, None, :] - a_cum[:, None, :, :]  # (B,t,s,H)
        L = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bthk,bshk->btsh", cc, bc)
        scores = cb * L * dtc[:, None, :, :]
        y_intra = jnp.einsum("btsh,bshp->bthp", scores, xc)
        y_inter = jnp.einsum("bch,bchk,bhpk->bchp", jnp.exp(a_cum), cc, h)
        w = jnp.exp(a_tot[:, None, :] - a_cum) * dtc  # (B,c,H)
        new_contrib = jnp.einsum("bch,bchp,bchk->bhpk", w, xc, bc)
        h_new = h * jnp.exp(a_tot)[..., None, None] + new_contrib
        return h_new, y_intra + y_inter

    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((Bz, H, P, N), jnp.float32)
    )
    h_final, ys = jax.lax.scan(body, h0, (xf, dtf, Bh, Ch), unroll=inner_unroll())
    y = ys.swapaxes(0, 1).reshape(Bz, S, H, P)
    if D is not None:
        y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h_final


def ssd_scan(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bmat: jnp.ndarray,
    Cmat: jnp.ndarray,
    D: Optional[jnp.ndarray] = None,
    *,
    chunk: int = 128,
    force_ref: bool = False,
    return_state: bool = False,
):
    S = x.shape[1]
    if force_ref:
        return ref.ssd_reference(x, dt, A, Bmat, Cmat, D, return_state=return_state)
    if _use_pallas() and S % chunk == 0 and not return_state:
        return ssd_pallas(x, dt, A, Bmat, Cmat, D, chunk=chunk, interpret=_interpret())
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:  # pad to chunk multiple (padded dt=0 -> identity steps)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, h_final = _ssd_chunked_scan_jnp(x, dt, A, Bmat, Cmat, D, chunk=chunk)
    y = y[:, :S] if pad else y
    if return_state:
        return y, h_final
    return y


def ssd_decode_step(
    state: jnp.ndarray,  # (B, H, P, N)
    x_t: jnp.ndarray,  # (B, H, P)
    dt_t: jnp.ndarray,  # (B, H)
    A: jnp.ndarray,  # (H,)
    B_t: jnp.ndarray,  # (B, G, N)
    C_t: jnp.ndarray,  # (B, G, N)
    D: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent step (long-context decode path)."""
    H = x_t.shape[1]
    G = B_t.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_t, rep, axis=1)  # (B,H,N)
    Ch = jnp.repeat(C_t, rep, axis=1)
    decay = jnp.exp(A[None, :] * dt_t)  # (B,H)
    state = state * decay[..., None, None] + (
        (dt_t[..., None] * x_t)[..., None] * Bh[:, :, None, :]
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    if D is not None:
        y = y + x_t * D[None, :, None]
    return state, y.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_chunked_jnp(
    q: jnp.ndarray,  # (B,S,H,D)
    k: jnp.ndarray,
    v: jnp.ndarray,
    i_gate: jnp.ndarray,  # (B,S,H)
    f_gate: jnp.ndarray,
    *,
    block_k: int = 2048,
) -> jnp.ndarray:
    """Blockwise stabilized mLSTM (same math as the Pallas kernel), scanning
    KV blocks with running (m, l, acc)."""
    B, S, H, D = q.shape
    scale = 1.0 / math.sqrt(D)
    block_k = min(block_k, S)
    assert S % block_k == 0
    nkb = S // block_k

    fcum = jnp.cumsum(jax.nn.log_sigmoid(f_gate.astype(jnp.float32)), axis=1)
    qf = q.astype(jnp.float32) * scale
    kb = k.reshape(B, nkb, block_k, H, D)
    vb = v.reshape(B, nkb, block_k, H, D)
    fb = fcum.reshape(B, nkb, block_k, H)
    ib = i_gate.astype(jnp.float32).reshape(B, nkb, block_k, H)
    q_pos = jnp.arange(S)

    def body(carry, inp):
        m, l, acc = carry  # (B,S,H), (B,S,H), (B,S,H,D)
        kblk, vblk, fblk, iblk, jb = inp
        k_pos = jb * block_k + jnp.arange(block_k)
        mask = k_pos[None, :] <= q_pos[:, None]  # (S, bk)
        dmat = (
            fcum[:, :, None, :] - fblk[:, None, :, :] + iblk[:, None, :, :]
        )  # (B,S,bk,H)
        dmat = jnp.where(mask[None, :, :, None], dmat, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(dmat, axis=2))
        dexp = jnp.where(
            mask[None, :, :, None], jnp.exp(dmat - m_new[:, :, None, :]), 0.0
        )
        s = jnp.einsum("bqhd,bshd->bqsh", qf, kblk.astype(jnp.float32))
        w = s * dexp
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(w, axis=2)
        wv = jnp.einsum("bqsh,bshd->bqhd", w, vblk.astype(jnp.float32))
        acc_new = acc * corr[..., None] + wv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, H), jnp.float32)
    a0 = jnp.zeros((B, S, H, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, a0),
        (
            kb.swapaxes(0, 1),
            vb.swapaxes(0, 1),
            fb.swapaxes(0, 1),
            ib.swapaxes(0, 1),
            jnp.arange(nkb),
        ),
        unroll=inner_unroll(),
    )
    denom = jnp.maximum(jnp.abs(l), jnp.exp(-m))
    return (acc / denom[..., None]).astype(q.dtype)


def mlstm_parallel(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    i_gate: jnp.ndarray,
    f_gate: jnp.ndarray,
    *,
    force_ref: bool = False,
    block_k: int = 2048,
) -> jnp.ndarray:
    S = q.shape[1]
    if force_ref:
        return ref.mlstm_reference(q, k, v, i_gate, f_gate)
    if _use_pallas() and S % 128 == 0:
        return mlstm_pallas(q, k, v, i_gate, f_gate, interpret=_interpret())
    if S <= 256:
        return ref.mlstm_reference(q, k, v, i_gate, f_gate)
    if S % block_k != 0:
        block_k = max(s for s in (128, 64, 32, 16, 8, 4, 2, 1) if S % s == 0)
    return _mlstm_chunked_jnp(q, k, v, i_gate, f_gate, block_k=block_k)


mlstm_decode_step = ref.mlstm_recurrent_step
slstm_scan = ref.slstm_reference
