"""Pallas TPU kernels for the model zoo's compute hot spots.

The paper (PyWren) has no kernel-level contribution — its contribution is the
runtime.  Kernels here serve the assigned architectures: flash attention
(+GQA/window/softcap), decode attention, Mamba2 SSD chunked scan, and the
mLSTM parallel cell.  Each has a pure-jnp oracle in ref.py and a jit-able
dispatcher in ops.py.
"""

from . import ops, ref
from .decode_attention import decode_attention_pallas
from .flash_attention import flash_attention_pallas
from .mamba2_ssd import ssd_pallas
from .mlstm_kernel import mlstm_pallas

__all__ = [
    "ops",
    "ref",
    "flash_attention_pallas",
    "decode_attention_pallas",
    "ssd_pallas",
    "mlstm_pallas",
]
