"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth for kernel tests (assert_allclose against
interpret-mode Pallas) AND the CPU execution path: this container has no TPU,
so models run these references; `ops.py` dispatches per platform.

All references are written naively (full materialization) for auditability —
scalability is the kernels' job, correctness is this file's job.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None or cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


def mha_reference(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, K, D)   K divides H (GQA)
    v: jnp.ndarray,  # (B, Sk, K, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,  # sliding window (None = full)
    logit_cap: Optional[float] = None,
    q_offset: int = 0,  # absolute position of q[0] (decode: cache length)
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Naive attention with GQA head grouping, causal/sliding masks, softcap."""
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    assert H % K == 0, (H, K)
    group = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    # expand kv heads to q heads
    k = jnp.repeat(k, group, axis=2)  # (B, Sk, H, D)
    v = jnp.repeat(v, group, axis=2)

    logits = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k)
    logits = softcap(logits, logit_cap)

    q_pos = jnp.arange(Sq)[:, None] + q_offset  # (Sq, 1)
    k_pos = jnp.arange(Sk)[None, :]  # (1, Sk)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None and window > 0:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def decode_attention_reference(
    q: jnp.ndarray,  # (B, H, D)          one new token
    k_cache: jnp.ndarray,  # (B, S, K, D)
    v_cache: jnp.ndarray,  # (B, S, K, D)
    cache_len: jnp.ndarray,  # (B,) int32 valid lengths
    *,
    logit_cap: Optional[float] = None,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """GQA handled by reshaping q to (B, K, G, D) — the KV cache is NEVER
    materialized with repeated heads (a repeat would change the divisible
    head count and make SPMD reshard a sequence-sharded cache: an
    all-gather of the whole cache per layer)."""
    B, H, D = q.shape
    _, S, K, _ = k_cache.shape
    group = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = (q * scale).reshape(B, K, group, D)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32)
    logits = softcap(logits, logit_cap)
    pos = jnp.arange(S)[None, :]  # (1, S)
    valid = pos < cache_len[:, None]
    if window is not None and window > 0:
        valid &= pos > (cache_len[:, None] - 1 - window)
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs.astype(q.dtype), v_cache)
    return out.reshape(B, H, D)


# ---------------------------------------------------------------------------
# Mamba2 / SSD (state-space dual) chunked scan
# ---------------------------------------------------------------------------

def ssd_reference(
    x: jnp.ndarray,  # (B, S, H, P)   inputs per head
    dt: jnp.ndarray,  # (B, S, H)      softplus'd timestep
    A: jnp.ndarray,  # (H,)           negative decay rate  (A < 0)
    Bmat: jnp.ndarray,  # (B, S, G, N)   input matrix  (G groups broadcast to H)
    Cmat: jnp.ndarray,  # (B, S, G, N)   output matrix
    D: Optional[jnp.ndarray] = None,  # (H,) skip connection
    *,
    init_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
    return_state: bool = False,
):
    """Sequential (exact) SSD recurrence:
        h_t = exp(A*dt_t) * h_{t-1} + dt_t * B_t x_t^T
        y_t = C_t . h_t  (+ D*x)
    Shapes follow Mamba2: per-head state (P, N)."""
    Bz, S, H, P = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bmat, rep, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(Cmat, rep, axis=2)

    decay = jnp.exp(A[None, None, :] * dt)  # (B,S,H)

    def step(h, inp):
        x_t, dt_t, dec_t, b_t, c_t = inp
        # h: (B,H,P,N)
        h = h * dec_t[..., None, None] + (dt_t[..., None, None] * x_t[..., None]) * b_t[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, c_t)
        return h, y

    h0 = (
        init_state
        if init_state is not None
        else jnp.zeros((Bz, H, P, N), dtype=jnp.float32)
    )
    xs = (
        x.swapaxes(0, 1).astype(jnp.float32),
        dt.swapaxes(0, 1).astype(jnp.float32),
        decay.swapaxes(0, 1).astype(jnp.float32),
        Bh.swapaxes(0, 1).astype(jnp.float32),
        Ch.swapaxes(0, 1).astype(jnp.float32),
    )
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = ys.swapaxes(0, 1)  # (B,S,H,P)
    if D is not None:
        y = y + x.astype(jnp.float32) * D[None, None, :, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, h_final
    return y


def ssd_chunked_reference(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bmat: jnp.ndarray,
    Cmat: jnp.ndarray,
    D: Optional[jnp.ndarray] = None,
    *,
    chunk: int = 64,
    init_state: Optional[jnp.ndarray] = None,
    return_state: bool = False,
):
    """Matmul-form chunked SSD (the algorithm the Pallas kernel implements):
    within-chunk quadratic attention-like term + cross-chunk state recurrence.
    Mathematically identical to `ssd_reference` (fp32 accumulation)."""
    Bz, S, H, P = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    xf = x.astype(jnp.float32).reshape(Bz, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bz, nc, chunk, H)
    Bh = jnp.repeat(Bmat, rep, axis=2).astype(jnp.float32).reshape(Bz, nc, chunk, H, N)
    Ch = jnp.repeat(Cmat, rep, axis=2).astype(jnp.float32).reshape(Bz, nc, chunk, H, N)

    a = A[None, None, None, :] * dtf  # (B,nc,c,H) log-decay increments
    a_cum = jnp.cumsum(a, axis=2)  # inclusive cumsum within chunk
    a_total = a_cum[:, :, -1, :]  # (B,nc,H)

    # within-chunk: y_intra[t] = sum_{s<=t} C_t B_s^T exp(a_cum[t]-a_cum[s]) dt_s x_s
    seg = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]  # (B,nc,t,s,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bnthk,bnshk->bntsh", Ch, Bh)  # (B,nc,t,s,H)
    scores = cb * L  # (B,nc,t,s,H)
    y_intra = jnp.einsum("bntsh,bnsh,bnshp->bnthp", scores, dtf, xf)

    # chunk states: h_chunk = sum_s exp(a_total - a_cum[s]) dt_s B_s x_s^T
    decay_to_end = jnp.exp(a_total[:, :, None, :] - a_cum)  # (B,nc,c,H)
    chunk_state = jnp.einsum(
        "bnch,bnch,bnchk,bnchp->bnhpk", decay_to_end, dtf, Bh, xf
    )

    # cross-chunk recurrence over nc
    def step(h, inp):
        a_tot, st = inp  # (B,H), (B,H,P,N)
        h_new = h * jnp.exp(a_tot)[:, :, None, None] + st
        return h_new, h  # emit state *entering* the chunk

    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((Bz, H, P, N), jnp.float32)
    )
    h_final, h_in = jax.lax.scan(
        step,
        h0,
        (a_total.swapaxes(0, 1), chunk_state.swapaxes(0, 1)),
    )
    h_in = h_in.swapaxes(0, 1)  # (B,nc,H,P,N) state entering each chunk

    # inter-chunk contribution: y_inter[t] = C_t exp(a_cum[t]) h_in
    y_inter = jnp.einsum("bnch,bnchk,bnhpk->bnchp", jnp.exp(a_cum), Ch, h_in)
    y = (y_intra + y_inter).reshape(Bz, S, H, P)
    if D is not None:
        y = y + x.astype(jnp.float32) * D[None, None, :, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, h_final
    return y


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell), parallel stabilized form
# ---------------------------------------------------------------------------

def mlstm_reference(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,  # (B, S, H, D)
    v: jnp.ndarray,  # (B, S, H, D)
    i_gate: jnp.ndarray,  # (B, S, H) input-gate preactivation
    f_gate: jnp.ndarray,  # (B, S, H) forget-gate preactivation
) -> jnp.ndarray:
    """Stabilized parallel mLSTM (xLSTM eq. 19-27):
        D_ts = exp(logsig-cumsum(f)[t] - ..[s] + i_s - m_t), lower-triangular
        out  = (QK^T/sqrt(d) * D) V / max(|row-sum|, exp(-m_t))
    """
    B, S, H, D = q.shape
    scale = 1.0 / math.sqrt(D)
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))  # (B,S,H)
    F = jnp.cumsum(logf, axis=1)
    # log decay matrix: F[t] - F[s] + i[s]  for s<=t
    dmat = F[:, :, None, :] - F[:, None, :, :] + i_gate.astype(jnp.float32)[:, None, :, :]
    tri = jnp.tril(jnp.ones((S, S), bool))
    dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)  # (B,S,1,H) row max
    dprime = jnp.exp(dmat - m)
    scores = jnp.einsum("bthd,bshd->btsh", q, k).astype(jnp.float32) * scale
    weights = scores * dprime
    denom = jnp.maximum(
        jnp.abs(jnp.sum(weights, axis=2, keepdims=True)), jnp.exp(-m)
    )  # (B,S,1,H)
    out = jnp.einsum("btsh,bshd->bthd", weights / denom, v.astype(jnp.float32))
    return out.astype(q.dtype)


def mlstm_recurrent_step(
    c: jnp.ndarray,  # (B, H, D, D) matrix memory
    n: jnp.ndarray,  # (B, H, D) normalizer
    m: jnp.ndarray,  # (B, H) stabilizer
    q_t: jnp.ndarray,  # (B, H, D)
    k_t: jnp.ndarray,
    v_t: jnp.ndarray,
    i_t: jnp.ndarray,  # (B, H)
    f_t: jnp.ndarray,  # (B, H)
) -> Tuple[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]:
    """O(1) decode step for the mLSTM cell (long_500k path)."""
    D = q_t.shape[-1]
    scale = 1.0 / math.sqrt(D)
    logf = jax.nn.log_sigmoid(f_t.astype(jnp.float32))
    m_new = jnp.maximum(logf + m, i_t.astype(jnp.float32))
    fgate = jnp.exp(logf + m - m_new)
    igate = jnp.exp(i_t.astype(jnp.float32) - m_new)
    c_new = fgate[..., None, None] * c + igate[..., None, None] * (
        v_t.astype(jnp.float32)[..., :, None] * k_t.astype(jnp.float32)[..., None, :]
    )
    n_new = fgate[..., None] * n + igate[..., None] * k_t.astype(jnp.float32)
    h_num = jnp.einsum("bhvk,bhk->bhv", c_new, q_t.astype(jnp.float32) * scale)
    h_den = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q_t.astype(jnp.float32) * scale)),
        jnp.exp(-m_new),
    )
    h = h_num / h_den[..., None]
    return (c_new, n_new, m_new), h.astype(q_t.dtype)


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory recurrent cell with exponential gating)
# ---------------------------------------------------------------------------

def slstm_reference(
    x: jnp.ndarray,  # (B, S, H, D) pre-projected inputs (per gate computed outside)
    gates_x: jnp.ndarray,  # (B, S, H, D, 4) input contributions to i,f,z,o
    r_kernel: jnp.ndarray,  # (H, D, D, 4) block-diagonal recurrent weights
    init: Optional[Tuple[jnp.ndarray, ...]] = None,
) -> jnp.ndarray:
    """sLSTM with exponential input gate, sigmoid/exp forget gate, stabilizer
    state (xLSTM eq. 7-18).  Strictly sequential: lax.scan over time."""
    B, S, H, D = x.shape

    def step(carry, gx_t):
        c, n, m, h = carry  # each (B,H,D) except m (B,H,D)
        rec = jnp.einsum("bhd,hdke->bhke", h, r_kernel)  # (B,H,D,4)
        pre = gx_t + rec
        i_t = pre[..., 0]
        f_t = pre[..., 1]
        z_t = jnp.tanh(pre[..., 2])
        o_t = jax.nn.sigmoid(pre[..., 3])
        logf = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(logf + m, i_t)
        igate = jnp.exp(i_t - m_new)
        fgate = jnp.exp(logf + m - m_new)
        c_new = fgate * c + igate * z_t
        n_new = fgate * n + igate
        h_new = o_t * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, h_new), h_new

    zeros = jnp.zeros((B, H, D), jnp.float32)
    carry0 = init if init is not None else (zeros, zeros, zeros - 1e9, zeros)
    gx = gates_x.swapaxes(0, 1).astype(jnp.float32)  # (S,B,H,D,4)
    _, hs = jax.lax.scan(step, carry0, gx)
    return hs.swapaxes(0, 1).astype(x.dtype)  # (B,S,H,D)
